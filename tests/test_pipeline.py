"""Pipelined emission: N-deep DMA rotation vs the synchronous pipeline.

Contracts of the buffer-depth PR:

* **numerical equivalence** — a ``Schedule.buffer_depth > 2`` changes how
  operands are *delivered* (explicit async-copy rotation, run-ahead
  ``depth − 1``), never what is computed: map, reduce, contraction and
  chained kernels must match the synchronous default bit-for-bit;
* **one budget** — ``ssr.stream_vmem_bytes`` is the single source of
  truth: the emitter's :meth:`StreamReport` and the autotuner's legality
  check must agree at every depth (the pre-PR code computed
  ``2 * block_bytes`` independently in both places);
* **legality** — depths outside ``[2, MAX_BUFFER_DEPTH]`` are rejected at
  both layers, and a deep × large candidate that busts the VMEM budget is
  filtered, not emitted;
* **zero-overhead dispatch** — a pipelined schedule rides the PR 5 cache
  paths: repeated calls are dict hits, no re-trace;
* **transparent resolution** — ``schedule=None`` picks a committed
  pipelined winner up from the autotune cache at every entry point with
  bit-identical results before/after the commit.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune, compiler, lowering, ssr
from repro.core.lowering import (DEFAULT_SCHEDULE, Schedule, ssr_call,
                                 ssr_chain_call)
from repro.core.ssr import (DEFAULT_BUFFER_DEPTH, MAX_BUFFER_DEPTH,
                            stream_vmem_bytes)
from repro.kernels import frontend

RNG = np.random.default_rng(7)


def arr(shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


DEPTHS = (3, 4)


class TestPipelinedEquivalence:
    """Depth > 2 must be numerically invisible at every lowering path."""

    @pytest.mark.parametrize("depth", DEPTHS)
    def test_map_bit_identical(self, depth):
        n = 5000
        nest = compiler.elementwise_nest(n)
        x = arr(n)
        body = lambda a: jnp.maximum(a, 0.0)  # noqa: E731
        want = ssr_call(nest, body, {"X": x}, mode="map")
        got = ssr_call(nest, body, {"X": x}, mode="map",
                       schedule=Schedule(buffer_depth=depth))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("depth", DEPTHS)
    def test_reduce_bit_identical(self, depth):
        n = 4096
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        want = ssr_call(nest, body, {"A": x, "B": y})
        got = ssr_call(nest, body, {"A": x, "B": y},
                       schedule=Schedule(buffer_depth=depth))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("depth", DEPTHS)
    def test_contraction_bit_identical(self, depth):
        m = n = 64
        k = 256
        a, b = arr((m, k)), arr((k, n))
        nest = compiler.gemm_nest(m, n, k)
        body = lambda x, y: jnp.dot(  # noqa: E731
            x, y, preferred_element_type=jnp.float32)
        want = ssr_call(nest, body, {"A": a, "B": b})
        got = ssr_call(nest, body, {"A": a, "B": b},
                       schedule=Schedule(buffer_depth=depth))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_chained_bit_identical(self):
        from repro.kernels.chained import _chain_nests

        n = 4096
        x, y = arr(n), arr(n)
        nests = _chain_nests(n, consumer_reads_w=False)
        bodies = (lambda a, b: (a - b) * (a - b), lambda t: t)
        want = ssr_chain_call(nests, bodies, {"X": x, "Y": y}, mode="reduce")
        got = ssr_chain_call(nests, bodies, {"X": x, "Y": y}, mode="reduce",
                             schedule=Schedule(buffer_depth=3))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("depth", DEPTHS)
    def test_waivered_gemv_bit_identical(self, depth):
        from repro.kernels.gemv import ssr_gemv

        a, x = arr((60, 256)), arr(256)
        want = ssr_gemv(a, x, schedule=DEFAULT_SCHEDULE)
        got = ssr_gemv(a, x, schedule=Schedule(buffer_depth=depth))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("depth", DEPTHS)
    def test_waivered_stencil_bit_identical(self, depth):
        from repro.kernels.stencil import TAPS, ssr_stencil1d

        x, w = arr(2048 + TAPS - 1), arr(TAPS) * 0.3
        want = ssr_stencil1d(x, w, schedule=DEFAULT_SCHEDULE)
        got = ssr_stencil1d(x, w, schedule=Schedule(buffer_depth=depth))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_emitter_actually_pipelines(self):
        # guard against the rotation silently falling back to sync: the
        # built kernel must advertise the requested depth and the
        # pipelined flag on a multi-step grid
        from repro.core.ssr import BlockStream, ssr_pallas
        from repro.core.stream import Direction

        ins = [BlockStream((1, 128), lambda i: (i, 0), Direction.READ, "x")]
        outs = [BlockStream((1, 128), lambda i: (i, 0),
                            Direction.WRITE, "o")]
        fn = ssr_pallas(lambda x, o: o.__setitem__(..., x[...]),
                        grid=(4,), in_streams=ins, out_streams=outs,
                        out_shapes=[jax.ShapeDtypeStruct((4, 128), jnp.float32)],
                        buffer_depth=3)
        assert fn.pipelined
        assert fn.buffer_depth == 3
        # a single-step grid has nothing to run ahead of: silently sync
        fn1 = ssr_pallas(lambda x, o: o.__setitem__(..., x[...]),
                         grid=(1,), in_streams=ins, out_streams=outs,
                         out_shapes=[jax.ShapeDtypeStruct((1, 128), jnp.float32)],
                         buffer_depth=3)
        assert not fn1.pipelined


class TestSharedBudget:
    """ssr report and autotune legality must agree through one helper."""

    @pytest.mark.parametrize("depth", (2, 3, 4))
    def test_report_matches_autotune_accounting(self, depth):
        n = 4096
        nest = compiler.dot_product_nest(n)
        sched = Schedule(buffer_depth=depth)
        lowered = autotune._lower_candidate(nest, sched)
        budget = autotune._stream_block_bytes(lowered)

        # rebuild the same accounting from the emitter's primitives: depth
        # buffers per stream block (in + synthesized out) + the reduce
        # accumulator scratch
        itemsize = 4
        expect = 0
        for s in lowered.in_streams:
            bb = int(np.prod(s.stream.block_shape)) * itemsize
            expect += stream_vmem_bytes(bb, depth)
        block = lowered.policy.rows * lowered.policy.lanes
        expect += stream_vmem_bytes(block * itemsize, depth)
        expect += block * itemsize
        assert budget == expect

    @pytest.mark.parametrize("depth", (2, 3, 4))
    def test_stream_report_scales_with_depth(self, depth):
        from repro.core.ssr import BlockStream, ssr_pallas
        from repro.core.stream import Direction

        ins = [BlockStream((8, 128), lambda i: (i, 0), Direction.READ, "x")]
        outs = [BlockStream((8, 128), lambda i: (i, 0),
                            Direction.WRITE, "o")]
        fn = ssr_pallas(lambda x, o: o.__setitem__(..., x[...]),
                        grid=(4,), in_streams=ins, out_streams=outs,
                        out_shapes=[jax.ShapeDtypeStruct((32, 128), jnp.float32)],
                        buffer_depth=depth)
        rep = fn.report(dtypes=[jnp.float32, jnp.float32])
        bb = 8 * 128 * 4
        assert rep.vmem_bytes == 2 * stream_vmem_bytes(bb, depth)

    def test_helper_is_linear_in_depth(self):
        assert stream_vmem_bytes(1000, 2) == 2000
        assert stream_vmem_bytes(1000, 5) == 5000


class TestDepthLegality:
    def test_ssr_pallas_rejects_out_of_range(self):
        from repro.core.ssr import BlockStream, ssr_pallas
        from repro.core.stream import Direction

        ins = [BlockStream((1, 128), lambda i: (i, 0), Direction.READ, "x")]
        outs = [BlockStream((1, 128), lambda i: (i, 0),
                            Direction.WRITE, "o")]
        for bad in (1, MAX_BUFFER_DEPTH + 1):
            with pytest.raises(ValueError, match="buffer_depth"):
                ssr_pallas(lambda x, o: None, grid=(4,), in_streams=ins,
                           out_streams=outs,
                           out_shapes=[jax.ShapeDtypeStruct((4, 128), jnp.float32)],
                           buffer_depth=bad)

    def test_autotune_rejects_out_of_range(self):
        nest = compiler.dot_product_nest(4096)
        for bad in (1, MAX_BUFFER_DEPTH + 1):
            ok, why = autotune.schedule_is_legal(
                nest, Schedule(buffer_depth=bad))
            assert not ok and "buffer_depth" in why

    def test_depth_times_block_busts_vmem_budget(self):
        # a geometry that fits double-buffered but not at depth 8:
        # depth * block_bytes is the quantity the budget must charge
        nest = compiler.gemm_nest(4096, 4096, 4096)
        big = Schedule(rows=16, lanes=512)
        deep = Schedule(rows=16, lanes=512, buffer_depth=MAX_BUFFER_DEPTH)
        ok_shallow, _ = autotune.schedule_is_legal(nest, big)
        ok_deep, why = autotune.schedule_is_legal(nest, deep)
        assert ok_shallow
        assert not ok_deep and "VMEM" in why

    def test_candidates_filtered_under_depth_budget(self):
        nest = compiler.dot_product_nest(1 << 14)
        cands = autotune.candidate_schedules(nest, quick=True)
        assert all(autotune.schedule_is_legal(nest, s)[0] for s in cands)
        assert {s.buffer_depth for s in cands} == {2, 3}

    def test_model_cost_rewards_depth(self):
        nest = compiler.elementwise_nest(1 << 16)
        c2 = autotune.model_cost(nest, DEFAULT_SCHEDULE)
        c3 = autotune.model_cost(nest, Schedule(buffer_depth=3))
        c4 = autotune.model_cost(nest, Schedule(buffer_depth=4))
        assert c4 < c3 < c2
        # the depth-2 charge is the historical STEP_COST model, exactly
        half = autotune.STEP_COST / 2.0
        assert half + half / (2 - 1) == autotune.STEP_COST


class TestZeroOverheadPipelinedDispatch:
    """A pipelined schedule must ride PR 5's cache paths unchanged."""

    def test_pipelined_ssr_call_traces_once(self):
        lowering.clear_caches()
        lowering.reset_dispatch_stats()
        n = 4096
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        sched = Schedule(buffer_depth=3)
        first = ssr_call(nest, body, {"A": x, "B": y}, schedule=sched)
        t1 = lowering.DISPATCH_STATS["traces"]
        assert lowering.DISPATCH_STATS["builds"] == 1
        second = ssr_call(nest, body, {"A": x, "B": y}, schedule=sched)
        assert lowering.DISPATCH_STATS["builds"] == 1
        assert lowering.DISPATCH_STATS["traces"] == t1
        assert lowering.DISPATCH_STATS["calls"] == 2
        np.testing.assert_array_equal(np.asarray(first), np.asarray(second))

    def test_depths_are_distinct_cache_entries(self):
        lowering.clear_caches()
        lowering.reset_dispatch_stats()
        n = 4096
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        ssr_call(nest, body, {"A": x, "B": y},
                 schedule=Schedule(buffer_depth=3))
        ssr_call(nest, body, {"A": x, "B": y},
                 schedule=Schedule(buffer_depth=4))
        assert lowering.DISPATCH_STATS["builds"] == 2
        ssr_call(nest, body, {"A": x, "B": y},
                 schedule=Schedule(buffer_depth=3))
        assert lowering.DISPATCH_STATS["builds"] == 2

    def test_pipelined_stream_kernel_traces_once(self):
        from repro.kernels.gemv import ssr_gemv

        a, x = arr((64, 256)), arr(256)
        sched = Schedule(buffer_depth=3)
        frontend.reset_dispatch_stats()
        ssr_gemv(a, x, schedule=sched)
        t1 = frontend.DISPATCH_STATS["traces"]
        b1 = frontend.DISPATCH_STATS["builds"]
        ssr_gemv(a, x, schedule=sched)
        assert frontend.DISPATCH_STATS["traces"] == t1
        assert frontend.DISPATCH_STATS["builds"] == b1


class TestTransparentResolution:
    """schedule=None must resolve a committed pipelined winner everywhere,
    with bit-identical results before and after the commit."""

    def _isolated_cache(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path))
        cache = autotune.global_cache()
        assert cache.path == str(tmp_path)
        return cache

    def test_ssr_call_entry(self, monkeypatch, tmp_path):
        self._isolated_cache(monkeypatch, tmp_path)
        n = 4096
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        before = ssr_call(nest, body, {"A": x, "B": y}, schedule=None)
        res = autotune.autotune(
            nest, body, {"A": x, "B": y}, mode="reduce",
            candidates=[DEFAULT_SCHEDULE, Schedule(buffer_depth=3)],
            iters=1, force=True)
        # pin a pipelined winner regardless of which one raced faster —
        # the contract under test is resolution, not the race
        autotune.global_cache().put(res.key, Schedule(buffer_depth=3))
        autotune._bump_epoch()
        after = ssr_call(nest, body, {"A": x, "B": y}, schedule=None)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_nest_kernel_entry(self, monkeypatch, tmp_path):
        from repro.kernels.reduction import ssr_dot

        self._isolated_cache(monkeypatch, tmp_path)
        x, y = arr(3000), arr(3000)
        before = ssr_dot(x, y)
        nest = compiler.dot_product_nest(3000)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        autotune.global_cache().put(key, Schedule(buffer_depth=3))
        autotune._bump_epoch()
        after = ssr_dot(x, y)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_gemv_entry(self, monkeypatch, tmp_path):
        from repro.kernels.gemv import ssr_gemv

        self._isolated_cache(monkeypatch, tmp_path)
        a, x = arr((64, 256)), arr(256)
        before = ssr_gemv(a, x)
        key = autotune.cache_key(compiler.gemv_nest(64, 256),
                                 {"A": a, "x": x}, mode="reduce",
                                 out_dtype="float32")
        autotune.global_cache().put(key, Schedule(buffer_depth=3))
        autotune._bump_epoch()
        after = ssr_gemv(a, x)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_cluster_call_entry(self, monkeypatch, tmp_path):
        from repro.parallel.cluster import cluster_call

        self._isolated_cache(monkeypatch, tmp_path)
        n = 4096
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        before = cluster_call(nest, body, {"A": x, "B": y}, cores=1)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        autotune.global_cache().put(key, Schedule(buffer_depth=3))
        autotune._bump_epoch()
        after = cluster_call(nest, body, {"A": x, "B": y}, cores=1)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


class TestScheduleSerialization:
    def test_buffer_depth_round_trips(self):
        s = Schedule(rows=16, buffer_depth=4)
        assert Schedule.from_json(s.to_json()) == s

    def test_old_cache_entries_default_to_depth_2(self):
        d = Schedule(rows=16).to_json()
        del d["buffer_depth"]          # a pre-PR persisted document
        assert Schedule.from_json(d).buffer_depth == DEFAULT_BUFFER_DEPTH

    def test_fingerprint_distinguishes_depths(self):
        nest = compiler.dot_product_nest(4096)
        f2 = autotune.schedule_fingerprint(nest, DEFAULT_SCHEDULE)
        f3 = autotune.schedule_fingerprint(
            nest, dataclasses.replace(DEFAULT_SCHEDULE, buffer_depth=3))
        assert f2 != f3


class TestPipelineFallbacks:
    def test_env_kill_switch_forces_sync(self, monkeypatch):
        monkeypatch.setenv("REPRO_DISABLE_PIPELINE", "1")
        assert not ssr.pipeline_supported()
        from repro.core.ssr import BlockStream, ssr_pallas
        from repro.core.stream import Direction

        ins = [BlockStream((1, 128), lambda i: (i, 0), Direction.READ, "x")]
        outs = [BlockStream((1, 128), lambda i: (i, 0),
                            Direction.WRITE, "o")]
        fn = ssr_pallas(lambda x, o: o.__setitem__(..., x[...]),
                        grid=(4,), in_streams=ins, out_streams=outs,
                        out_shapes=[jax.ShapeDtypeStruct((4, 128), jnp.float32)],
                        buffer_depth=3)
        assert not fn.pipelined
        x = arr((4, 128))
        np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))

    def test_supported_here(self):
        assert ssr.pipeline_supported()
