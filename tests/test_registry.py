"""Registry-driven kernel equivalence: every registered kernel's variants
must agree on non-multiple-of-block sizes (padding correctness) and on the
paper's §4.2 sizes.  Adding a kernel to the registry automatically adds it
here — no per-kernel test edits."""

import numpy as np
import pytest

import jax

from repro.core import ssr_region
from repro.kernels import ops, registry

EXPECTED = {"reduction", "scan", "relu", "stencil1d", "stencil2d", "gemv",
            "gemm", "fft", "bitonic", "attention",
            # fused (stream-chained) variants: ssr = fused single kernel,
            # baseline = unfused two-kernel composition
            "gemv_relu", "stencil1d_relu", "sum_sq_diff", "axpy_dot"}


def _assert_close(got, want, tol):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if tol["rtol"] == 0.0 and tol["atol"] == 0.0:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), **tol)


class TestRegistry:
    def test_suite_registered(self):
        assert EXPECTED <= set(registry.names())

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError, match="no kernel"):
            registry.get("nope")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="registered twice"):
            registry.register_kernel("reduction")(lambda: None)

    def test_entries_have_examples(self):
        for entry in registry.entries():
            assert entry.example is not None, entry.name
            args, kwargs = entry.example(np.random.default_rng(0))
            assert isinstance(args, tuple) and isinstance(kwargs, dict)


@pytest.mark.parametrize("odd", [False, True], ids=["paper-size", "odd-size"])
@pytest.mark.parametrize("name", sorted(EXPECTED))
class TestEquivalence:
    def test_ssr_matches_ref(self, name, odd):
        entry = registry.get(name)
        args, kwargs = entry.example(np.random.default_rng(3), odd=odd)
        _assert_close(entry.ssr(*args, **kwargs),
                      entry.ref(*args, **kwargs), entry.tol)

    def test_baseline_matches_ref(self, name, odd):
        entry = registry.get(name)
        if entry.baseline is None:
            pytest.skip(f"{name}: no baseline variant (paper has none)")
        args, kwargs = entry.example(np.random.default_rng(3), odd=odd)
        _assert_close(entry.baseline(*args, **kwargs),
                      entry.ref(*args, **kwargs), entry.tol)


class TestOnePathToSilicon:
    """The unified-frontend contract: every kernel either rides the
    compiler (NestKernel) or declares why it cannot (lowering_waiver)."""

    def test_no_launch_without_waiver(self):
        import importlib
        import repro.kernels.frontend as fe
        from repro.kernels.registry import _KERNEL_MODULES

        holdouts = {}
        for modname in _KERNEL_MODULES:
            mod = importlib.import_module(f"repro.kernels.{modname}")
            for attr in vars(mod).values():
                if isinstance(attr, (fe.StreamKernel, fe.ChainedKernel)):
                    assert attr.lowering_waiver.strip(), attr.name
                    holdouts[attr.name] = attr.lowering_waiver
        # the migrated kernels must NOT appear as hand-scheduled holdouts
        assert {"gemm", "reduction", "relu"}.isdisjoint(holdouts)
        # the declared holdouts are exactly the known hard patterns
        assert set(holdouts) == {"gemv", "scan", "stencil1d", "stencil2d",
                                 "fft", "bitonic", "attention",
                                 "gemv_relu", "stencil1d_relu"}

    def test_waiver_required_at_construction(self):
        from repro.kernels.frontend import Launch, StreamKernel

        with pytest.raises(ValueError, match="lowering_waiver"):
            StreamKernel("rogue", prepare=lambda x: ((x,), None, None),
                         launch=lambda s, x: Launch((1,), (), (), ()),
                         body=lambda s: (lambda x_ref, o_ref: None))

    def test_gemm_and_stencil_have_full_variant_coverage(self):
        for name in ("gemm", "stencil1d"):
            entry = registry.get(name)
            assert entry.baseline is not None, name
            assert entry.cluster is not None, name


class TestDispatch:
    def test_ssrcfg_off_is_ref_path(self):
        entry = registry.get("relu")
        args, kwargs = entry.example(np.random.default_rng(1))
        got = registry.dispatch("relu", *args, ssr=False, **kwargs)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(entry.ref(*args)))

    def test_region_flips_engine_not_semantics(self):
        entry = registry.get("reduction")
        args, _ = entry.example(np.random.default_rng(2))
        with ssr_region(True):
            streamed = ops.dot(*args)
        with ssr_region(False):
            plain = ops.dot(*args)
        np.testing.assert_allclose(np.asarray(streamed), np.asarray(plain),
                                   rtol=1e-3, atol=1e-3)
