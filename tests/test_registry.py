"""Registry-driven kernel equivalence: every registered kernel's variants
must agree on non-multiple-of-block sizes (padding correctness) and on the
paper's §4.2 sizes.  Adding a kernel to the registry automatically adds it
here — no per-kernel test edits."""

import numpy as np
import pytest

import jax

from repro.core import ssr_region
from repro.kernels import ops, registry

EXPECTED = {"reduction", "scan", "relu", "stencil1d", "stencil2d", "gemv",
            "gemm", "fft", "bitonic", "attention",
            # fused (stream-chained) variants: ssr = fused single kernel,
            # baseline = unfused two-kernel composition
            "gemv_relu", "stencil1d_relu", "sum_sq_diff", "axpy_dot",
            # CSR indirection streams: ssr = compiled gather path,
            # baseline = monolithic explicit-take kernel
            "spmv", "spmm"}

#: The waiver ratchet's pinned holdout set: the kernels allowed to stay on
#: hand-scheduled ``Launch`` paths, each with a ``lowering_waiver`` stating
#: why the block-granular AGU model cannot express them.  This set may only
#: ever SHRINK — migrating a kernel to ``NestKernel`` removes its name
#: here; adding a name (or re-regressing a migrated kernel to a Launch) is
#: a hard failure of :class:`TestWaiverRatchet`.
WAIVER_HOLDOUTS = frozenset({"scan", "fft", "bitonic"})

#: Kernels that ride the compiled ``NestKernel`` path and must never
#: regress to a hand-scheduled ``Launch``.  The halo-read and
#: online-rescaled-accumulator lowerings (DESIGN.md §13) moved the whole
#: stencil/attention family off their waivers.
NEST_MIGRATED = frozenset({
    "gemm", "reduction", "relu", "spmv", "spmm",
    "gemv", "stencil1d", "stencil2d", "attention",
    "gemv_relu", "stencil1d_relu"})


def _assert_close(got, want, tol):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if tol["rtol"] == 0.0 and tol["atol"] == 0.0:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), **tol)


class TestRegistry:
    def test_suite_registered(self):
        assert EXPECTED <= set(registry.names())

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError, match="no kernel"):
            registry.get("nope")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="registered twice"):
            registry.register_kernel("reduction")(lambda: None)

    def test_entries_have_examples(self):
        for entry in registry.entries():
            assert entry.example is not None, entry.name
            args, kwargs = entry.example(np.random.default_rng(0))
            assert isinstance(args, tuple) and isinstance(kwargs, dict)


@pytest.mark.parametrize("odd", [False, True], ids=["paper-size", "odd-size"])
@pytest.mark.parametrize("name", sorted(EXPECTED))
class TestEquivalence:
    def test_ssr_matches_ref(self, name, odd):
        entry = registry.get(name)
        args, kwargs = entry.example(np.random.default_rng(3), odd=odd)
        _assert_close(entry.ssr(*args, **kwargs),
                      entry.ref(*args, **kwargs), entry.tol)

    def test_baseline_matches_ref(self, name, odd):
        entry = registry.get(name)
        if entry.baseline is None:
            pytest.skip(f"{name}: no baseline variant (paper has none)")
        args, kwargs = entry.example(np.random.default_rng(3), odd=odd)
        _assert_close(entry.baseline(*args, **kwargs),
                      entry.ref(*args, **kwargs), entry.tol)


def _collect_kernel_instances():
    """(waiver holdouts, NestKernel-backed names) across every kernel
    module — the raw material of the one-path-to-silicon contract."""
    import importlib
    import repro.kernels.frontend as fe
    from repro.kernels.registry import _KERNEL_MODULES

    holdouts, nest_backed = {}, set()
    for modname in _KERNEL_MODULES:
        mod = importlib.import_module(f"repro.kernels.{modname}")
        for attr in vars(mod).values():
            if isinstance(attr, (fe.StreamKernel, fe.ChainedKernel)):
                holdouts[attr.name] = attr.lowering_waiver
            elif isinstance(attr, fe.NestKernel):
                nest_backed.add(attr.name)
    return holdouts, nest_backed


class TestOnePathToSilicon:
    """The unified-frontend contract: every kernel either rides the
    compiler (NestKernel) or declares why it cannot (lowering_waiver)."""

    def test_no_launch_without_waiver(self):
        holdouts, _ = _collect_kernel_instances()
        for name, waiver in holdouts.items():
            assert waiver.strip(), name
        # the migrated kernels must NOT appear as hand-scheduled holdouts
        assert {"gemm", "reduction", "relu"}.isdisjoint(holdouts)
        # the declared holdouts are exactly the known hard patterns
        assert set(holdouts) == WAIVER_HOLDOUTS


class TestWaiverRatchet:
    """The waiver count only ratchets DOWN.

    A new hand-scheduled kernel (or a migrated kernel regressing to a
    ``Launch``) would silently erode the paper's one-compiler story; this
    test makes that a loud, named failure.  To *shrink* the set after a
    migration, remove the name from ``WAIVER_HOLDOUTS`` and add it to
    ``NEST_MIGRATED`` — never the other direction.
    """

    def test_waiver_set_only_shrinks(self):
        holdouts, _ = _collect_kernel_instances()
        new = set(holdouts) - WAIVER_HOLDOUTS
        assert not new, (
            f"new lowering_waiver(s) {sorted(new)}: hand-scheduled Launch "
            "kernels may not be added — express the pattern as a LoopNest "
            "(NestKernel) instead")

    def test_migrated_kernels_stay_migrated(self):
        holdouts, nest_backed = _collect_kernel_instances()
        regressed = NEST_MIGRATED & set(holdouts)
        assert not regressed, (
            f"{sorted(regressed)} regressed from NestKernel to a "
            "hand-scheduled Launch")
        missing = NEST_MIGRATED - nest_backed
        assert not missing, (
            f"{sorted(missing)} no longer have a NestKernel instance")

    def test_holdouts_and_migrated_are_disjoint(self):
        assert not WAIVER_HOLDOUTS & NEST_MIGRATED

    def test_waiver_required_at_construction(self):
        from repro.kernels.frontend import Launch, StreamKernel

        with pytest.raises(ValueError, match="lowering_waiver"):
            StreamKernel("rogue", prepare=lambda x: ((x,), None, None),
                         launch=lambda s, x: Launch((1,), (), (), ()),
                         body=lambda s: (lambda x_ref, o_ref: None))

    def test_gemm_and_stencil_have_full_variant_coverage(self):
        for name in ("gemm", "stencil1d"):
            entry = registry.get(name)
            assert entry.baseline is not None, name
            assert entry.cluster is not None, name


class TestDispatch:
    def test_ssrcfg_off_is_ref_path(self):
        entry = registry.get("relu")
        args, kwargs = entry.example(np.random.default_rng(1))
        got = registry.dispatch("relu", *args, ssr=False, **kwargs)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(entry.ref(*args)))

    def test_region_flips_engine_not_semantics(self):
        entry = registry.get("reduction")
        args, _ = entry.example(np.random.default_rng(2))
        with ssr_region(True):
            streamed = ops.dot(*args)
        with ssr_region(False):
            plain = ops.dot(*args)
        np.testing.assert_allclose(np.asarray(streamed), np.asarray(plain),
                                   rtol=1e-3, atol=1e-3)
