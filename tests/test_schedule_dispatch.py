"""Schedule threading + zero-overhead dispatch (lowering/frontend/cluster).

The tentpole contracts of the autotuner PR:

* every lowering entry point accepts a non-default :class:`Schedule` and
  produces identical numerics (the schedule changes *how*, never *what*);
* a repeated identical call is a cache hit on the jitted prepare→engine→
  finish pipeline — no re-trace, no eager pad/trim dispatch (asserted via
  trace counters that only move while tracing);
* ``NestKernel`` resolves tuned schedules from the persistent cache
  transparently, and the cluster layer picks the per-core tile's schedule.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import autotune, compiler, lowering
from repro.core.lowering import (DEFAULT_SCHEDULE, LoweringError, Schedule,
                                 lower_nest, plan_stats, ssr_call,
                                 ssr_chain_call)
from repro.kernels import frontend

RNG = np.random.default_rng(3)


def arr(shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


class TestPowerOfTwoRegression:
    def test_zero_rejected(self):
        with pytest.raises(ValueError, match="power-of-two"):
            frontend.require_power_of_two(0, "fft input")

    def test_negative_rejected(self):
        with pytest.raises(ValueError, match="power-of-two"):
            frontend.require_power_of_two(-4, "fft input")

    @pytest.mark.parametrize("n", [1, 2, 1024])
    def test_powers_accepted(self, n):
        frontend.require_power_of_two(n, "ok")

    @pytest.mark.parametrize("n", [3, 12, 1000])
    def test_non_powers_rejected(self, n):
        with pytest.raises(ValueError, match="power-of-two"):
            frontend.require_power_of_two(n, "bad")


class TestScheduleEquivalence:
    """Non-default schedules must be bit-for-bit (or fp-tolerance) neutral."""

    def test_reduce_across_block_geometries(self):
        n = 5000
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        want = ssr_call(nest, body, {"A": x, "B": y})
        for sched in (Schedule(rows=4), Schedule(rows=16),
                      Schedule(rows=16, lanes=256), Schedule(lanes=256)):
            got = ssr_call(nest, body, {"A": x, "B": y}, schedule=sched)
            np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_map_trim_with_odd_size(self):
        n = 1025   # exercises _trim_output under a non-default policy
        nest = compiler.elementwise_nest(n)
        x = arr(n)
        body = lambda a: jnp.maximum(a, 0.0)  # noqa: E731
        want = ssr_call(nest, body, {"X": x}, mode="map")
        got = ssr_call(nest, body, {"X": x}, mode="map",
                       schedule=Schedule(rows=16, lanes=256))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_chain_with_schedule(self):
        from repro.kernels.chained import _chain_nests

        n = 4096
        x, y = arr(n), arr(n)
        nests = _chain_nests(n, consumer_reads_w=False)
        bodies = (lambda a, b: (a - b) * (a - b), lambda t: t)
        want = ssr_chain_call(nests, bodies, {"X": x, "Y": y}, mode="reduce")
        got = ssr_chain_call(nests, bodies, {"X": x, "Y": y}, mode="reduce",
                             schedule=Schedule(rows=16))
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_gemm_tile_factors_and_axis_order(self):
        m, n, k = 32, 32, 256
        a, b = arr((m, k)), arr((k, n))
        want = jnp.dot(a, b)

        def run(sched):
            from repro.kernels.gemm import ssr_matmul

            return ssr_matmul(a, b, out_dtype=jnp.float32, schedule=sched)

        # small tile targets force real multi-tile grids (m: 4, k: 2)
        base = Schedule(rows_tile_factor=1, lanes_tile_factor=1)
        np.testing.assert_allclose(np.asarray(run(base)), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        # permuting the parallel axes re-walks the same tiles: identical
        permuted = Schedule(rows_tile_factor=1, lanes_tile_factor=1,
                            axis_order=(1, 0, 2))
        np.testing.assert_allclose(np.asarray(run(permuted)),
                                   np.asarray(run(base)), rtol=1e-6)

    def test_gemm_axis_order_changes_grid_order(self):
        nest = compiler.gemm_nest(32, 32, 256)
        plan = lowering._plan_for(nest, 3)
        base = lower_nest(plan, schedule=Schedule(rows_tile_factor=1,
                                                  lanes_tile_factor=1))
        perm = lower_nest(plan, schedule=Schedule(rows_tile_factor=1,
                                                  lanes_tile_factor=1,
                                                  axis_order=(1, 0, 2)))
        assert base.grid == (4, 1, 2)
        assert perm.grid == (1, 4, 2)
        assert base.semantics == ("parallel", "parallel", "arbitrary")
        assert perm.semantics == ("parallel", "parallel", "arbitrary")

    def test_axis_order_illegal_cases(self):
        nest = compiler.gemm_nest(32, 32, 256)
        plan = lowering._plan_for(nest, 3)
        with pytest.raises(LoweringError, match="not a permutation"):
            lower_nest(plan, schedule=Schedule(axis_order=(0, 1)))
        with pytest.raises(LoweringError, match="trailing"):
            lower_nest(plan, schedule=Schedule(axis_order=(2, 0, 1)))

    def test_flat_path_rejects_axis_order(self):
        nest = compiler.dot_product_nest(2048)
        with pytest.raises(LoweringError, match="level-mapped"):
            ssr_call(nest, lambda a, b: a * b,
                     {"A": arr(2048), "B": arr(2048)},
                     schedule=Schedule(axis_order=(0,)))

    def test_stencil_widths_identical(self):
        from repro.kernels.stencil import TAPS, ssr_stencil1d

        x, w = arr(1024 + TAPS - 1), arr(TAPS) * 0.3
        want = ssr_stencil1d(x, w)
        for lanes in (256, 512, 1024):
            got = ssr_stencil1d(x, w, schedule=Schedule(lanes=lanes))
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_plan_stats_untouched_by_schedule(self):
        # the Eq. (1)-(3) verdict is schedule-independent: geometry moves
        # blocks, not instructions
        nest = compiler.dot_product_nest(4096)
        s = plan_stats(nest)
        assert s.n_base > s.n_ssr


class TestZeroOverheadDispatch:
    """Second identical call = dict hit; trace counters must not move."""

    def test_ssr_call_traces_once(self):
        lowering.clear_caches()
        lowering.reset_dispatch_stats()
        n = 2048
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        first = ssr_call(nest, body, {"A": x, "B": y})
        after_first = dict(lowering.DISPATCH_STATS)
        assert after_first["builds"] == 1
        assert after_first["traces"] >= 1
        second = ssr_call(nest, body, {"A": x, "B": y})
        assert lowering.DISPATCH_STATS["builds"] == 1
        assert lowering.DISPATCH_STATS["traces"] == after_first["traces"]
        assert lowering.DISPATCH_STATS["calls"] == 2
        np.testing.assert_allclose(float(first), float(second))

    def test_ssr_chain_call_traces_once(self):
        from repro.kernels.chained import _chain_nests

        lowering.clear_caches()
        lowering.reset_dispatch_stats()
        n = 2048
        nests = _chain_nests(n, consumer_reads_w=False)
        bodies = (lambda a, b: (a - b) * (a - b), lambda t: t)
        ops = {"X": arr(n), "Y": arr(n)}
        ssr_chain_call(nests, bodies, ops, mode="reduce")
        t1 = lowering.DISPATCH_STATS["traces"]
        ssr_chain_call(nests, bodies, ops, mode="reduce")
        assert lowering.DISPATCH_STATS["traces"] == t1
        assert lowering.DISPATCH_STATS["builds"] == 1

    def test_nest_kernel_pipeline_traces_once(self):
        from repro.kernels.reduction import ssr_dot

        x, y = arr(3000), arr(3000)
        frontend.reset_dispatch_stats()
        ssr_dot(x, y)
        t1 = frontend.DISPATCH_STATS["traces"]
        b1 = frontend.DISPATCH_STATS["builds"]
        ssr_dot(x, y)
        assert frontend.DISPATCH_STATS["traces"] == t1
        assert frontend.DISPATCH_STATS["builds"] == b1
        assert frontend.DISPATCH_STATS["calls"] >= 2

    def test_stream_kernel_pipeline_traces_once(self):
        from repro.kernels.stencil import TAPS, ssr_stencil1d

        x, w = arr(777 + TAPS - 1), arr(TAPS) * 0.3
        frontend.reset_dispatch_stats()
        ssr_stencil1d(x, w)
        t1 = frontend.DISPATCH_STATS["traces"]
        ssr_stencil1d(x, w)
        assert frontend.DISPATCH_STATS["traces"] == t1

    def test_monolithic_kernel_pipeline_traces_once(self):
        from repro.kernels.relu import baseline_relu

        x = arr(999)
        frontend.reset_dispatch_stats()
        want = baseline_relu(x)
        t1 = frontend.DISPATCH_STATS["traces"]
        got = baseline_relu(x)
        assert frontend.DISPATCH_STATS["traces"] == t1
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_new_shape_rebuilds(self):
        from repro.kernels.reduction import ssr_dot

        frontend.reset_dispatch_stats()
        ssr_dot(arr(1111), arr(1111))
        b1 = frontend.DISPATCH_STATS["builds"]
        ssr_dot(arr(2222), arr(2222))
        assert frontend.DISPATCH_STATS["builds"] == b1 + 1


class TestTransparentTuning:
    """NestKernel + cluster pick up committed winners without code changes."""

    @pytest.fixture
    def tuned_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "sch"))
        # the global cache re-resolves its path lazily
        yield autotune.global_cache()

    def test_nest_kernel_resolves_committed_schedule(self, tuned_env):
        from repro.kernels import reduction

        n = 2048
        x, y = arr(n), arr(n)
        nest = compiler.dot_product_nest(n)
        committed = Schedule(rows=16, lanes=128)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, committed)
        assert reduction._ssr.schedule_for(x, y) == committed
        # and the call still matches the oracle under the tuned schedule
        got = reduction.ssr_dot(x, y)
        np.testing.assert_allclose(float(got), float(jnp.dot(x, y)),
                                   rtol=1e-4)

    def test_commit_takes_effect_without_restart(self, tuned_env):
        from repro.kernels import reduction

        n = 4096
        x, y = arr(n), arr(n)
        assert reduction._ssr.schedule_for(x, y) == DEFAULT_SCHEDULE
        reduction.ssr_dot(x, y)          # builds the default pipeline
        nest = compiler.dot_product_nest(n)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, Schedule(rows=32))   # epoch bump
        assert reduction._ssr.schedule_for(x, y) == Schedule(rows=32)
        got = reduction.ssr_dot(x, y)    # rebuilt under the new schedule
        np.testing.assert_allclose(float(got), float(jnp.dot(x, y)),
                                   rtol=1e-4)

    def test_cluster_cores1_accepts_schedule(self):
        from repro.parallel.cluster import LAST_DISPATCH, cluster_call

        n = 2048
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        want = cluster_call(nest, body, {"A": x, "B": y}, cores=1)
        LAST_DISPATCH.clear()
        got = cluster_call(nest, body, {"A": x, "B": y}, cores=1,
                           schedule=Schedule(rows=16))
        assert LAST_DISPATCH["schedule"] == Schedule(rows=16)
        assert LAST_DISPATCH["cores"] == 1
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_stencil_resolves_committed_width(self, tuned_env):
        # the migrated stencil rides transparent tuning: a schedule
        # committed under the halo nest's key must reach plain
        # ssr_stencil1d (and be exactly as wide as the default — per-tap
        # fmadd order is width-independent)
        from repro.kernels.stencil import TAPS, _ssr_1d, ssr_stencil1d

        n = 1024
        x, w = arr(n + TAPS - 1), arr(TAPS) * 0.3
        want = ssr_stencil1d(x, w)      # default schedule (cache miss)
        key = autotune.cache_key(compiler.stencil_nest(n, TAPS),
                                 {"x": x, "w": w}, mode="reduce",
                                 out_dtype="float32")
        committed = Schedule(lanes=512)
        tuned_env.put(key, committed)
        assert _ssr_1d.schedule_for(x, w) == committed
        got = ssr_stencil1d(x, w)       # resolves the committed schedule
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_cluster_cores1_stays_bit_identical_after_commit(self, tuned_env):
        # regression: entry.ssr resolves tuned schedules via NestKernel,
        # and the cores=1 cluster bypass must resolve the SAME schedule —
        # otherwise a committed winner silently breaks the bit-equality
        # between the single-core registry path and cores=1
        from repro.kernels import reduction

        n = 2048
        x, y = arr(n), arr(n)
        nest = compiler.dot_product_nest(n)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, Schedule(rows=4, lanes=512))
        got = reduction.cluster_dot(x, y, cores=1)
        want = reduction.ssr_dot(x, y)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        from repro.parallel.cluster import LAST_DISPATCH

        assert LAST_DISPATCH["schedule"] == Schedule(rows=4, lanes=512)
        assert LAST_DISPATCH["tuned"]

    def test_explicit_policy_is_never_overridden_by_commit(self, tuned_env):
        # regression: a caller pinning a legacy policy= must get exactly
        # that geometry even when the autotuner has committed a different
        # winner for the same problem — the lookup only fires for the
        # fully-default call
        from repro.core.lowering import BlockPolicy
        from repro.parallel.cluster import LAST_DISPATCH, cluster_call

        n = 2048
        x, y = arr(n), arr(n)
        nest = compiler.dot_product_nest(n)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, Schedule(rows=16, lanes=256))
        body = lambda a, b: a * b  # noqa: E731
        LAST_DISPATCH.clear()
        pinned = cluster_call(nest, body, {"A": x, "B": y}, cores=1,
                              policy=BlockPolicy(rows=4))
        assert LAST_DISPATCH["schedule"] == Schedule(rows=4)
        assert not LAST_DISPATCH["tuned"]
        want = ssr_call(nest, body, {"A": x, "B": y},
                        policy=BlockPolicy(rows=4))
        np.testing.assert_array_equal(np.asarray(pinned), np.asarray(want))

    def test_cluster_per_core_lookup_uses_shard_shapes(self, tuned_env):
        # commit a winner for the PER-CORE tile (n/2) and check the
        # cluster layer's lookup helper resolves it for cores=2
        from repro.parallel import cluster as pc

        n = 4096
        sub = compiler.dot_product_nest(n // 2)
        x, y = arr(n), arr(n)
        shard_ops = {"A": ((n // 2,), "float32"),
                     "B": ((n // 2,), "float32")}
        key = autotune.cache_key(sub, shard_ops, mode="reduce",
                                 out_dtype="float32")
        committed = Schedule(rows=16)
        tuned_env.put(key, committed)
        got = pc._core_schedule([sub], {"A": x, "B": y},
                                mode="reduce", out_dtype=jnp.float32)
        assert got == committed
