"""Substrate tests: optimizer, schedules, data pipeline, checkpointing."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.models import ModelConfig
from repro.models.config import ScanGroup
from repro.optim import adamw, compress

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                                clip_norm=None)
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((3,))}
        state = adamw.init(params, cfg)
        for _ in range(300):
            grads = jax.grad(
                lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw.update(grads, state, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_bf16_moments(self):
        cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.ones((4, 4))}
        state = adamw.init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        grads = {"w": jnp.ones((4, 4))}
        params, state, _ = adamw.update(grads, state, params, cfg)
        assert state["v"]["w"].dtype == jnp.bfloat16

    def test_clip_norm(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0, learning_rate=1.0,
                                weight_decay=0.0)
        params = {"w": jnp.zeros((2,))}
        state = adamw.init(params, cfg)
        huge = {"w": jnp.asarray([3e4, 4e4])}
        p2, _, m = adamw.update(huge, state, params, cfg)
        assert float(m["grad_norm"]) == pytest.approx(5e4, rel=1e-3)
        assert bool(jnp.isfinite(p2["w"]).all())

    def test_no_decay_on_1d(self):
        cfg = adamw.AdamWConfig(learning_rate=0.0, weight_decay=1.0)
        # lr=0 ⇒ params unchanged regardless of decay
        params = {"norm": jnp.ones((4,)), "w": jnp.ones((4, 4))}
        state = adamw.init(params, cfg)
        grads = jax.tree.map(jnp.zeros_like, params)
        p2, _, _ = adamw.update(grads, state, params, cfg)
        np.testing.assert_allclose(np.asarray(p2["norm"]), 1.0)

    def test_warmup_cosine(self):
        sched = adamw.warmup_cosine(peak=1.0, warmup=10, total=110)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-3)


class TestCompression:
    @given(scale=st.floats(1e-5, 1e4))
    @settings(max_examples=30, deadline=None)
    def test_quant_roundtrip_error_bounded(self, scale):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(256)
                        * scale, jnp.float32)
        q, s = compress.quantize(x)
        err = np.abs(np.asarray(compress.dequantize(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-9

    def test_error_feedback_accumulates(self):
        g = jnp.full((64,), 0.3e-2)
        residual = jnp.zeros((64,))
        total = jnp.zeros((64,))
        for _ in range(50):
            q, s, residual = compress.compress_leaf(g, residual)
            total = total + compress.dequantize(q, s)
        # with error feedback, the long-run mean equals the true gradient
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   rtol=0.05)


class TestDataPipeline:
    def test_deterministic_per_step(self):
        cfg = ModelConfig(name="t", family="dense", d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=101,
                          groups=(ScanGroup((("attn", "mlp"),), 1),),
                          remat=False)
        dcfg = pipeline.DataConfig(global_batch=4, seq_len=16, seed=3)
        a = pipeline.make_batch(cfg, dcfg, step=5)
        b = pipeline.make_batch(cfg, dcfg, step=5)
        c = pipeline.make_batch(cfg, dcfg, step=6)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))
        assert int(a["tokens"].max()) < 101
        # labels are next-token shifted
        np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                      np.asarray(a["labels"][:, :-1]))

    def test_input_specs_match_batches(self):
        for fe, fl in ((None, 0), ("audio", 8), ("vision", 8)):
            cfg = ModelConfig(
                name="t", family="dense", d_model=32, num_heads=2,
                num_kv_heads=2, d_ff=64, vocab_size=101,
                groups=(ScanGroup((("attn", "mlp"),), 1),),
                frontend=fe, frontend_len=fl, remat=False)
            dcfg = pipeline.DataConfig(global_batch=2, seq_len=8)
            specs = pipeline.input_specs(cfg, dcfg)
            batch = pipeline.make_batch(cfg, dcfg, 0)
            assert set(specs) == set(batch)
            for k in specs:
                assert specs[k].shape == batch[k].shape, k
                assert specs[k].dtype == batch[k].dtype, k


class TestCheckpoint:
    def _state(self, x=1.0):
        return {"params": {"w": jnp.full((4, 4), x),
                           "b": jnp.arange(4.0)},
                "opt": {"m": {"w": jnp.zeros((4, 4)),
                              "b": jnp.zeros((4,))},
                        "count": jnp.asarray(3)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = self._state(2.5)
        mgr.save(10, state)
        assert mgr.latest_step() == 10
        restored = mgr.restore(10, jax.tree.map(jnp.zeros_like, state))
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_and_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state(float(s)), blocking=False)
        mgr.wait()
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4

    def test_manifest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, self._state(), extra={"data_step": 7})
        m = mgr.manifest(7)
        assert m["step"] == 7
        assert m["extra"]["data_step"] == 7
        assert "params/w" in m["leaves"]

    def test_atomic_no_partial_on_existing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._state(1.0))
        mgr.save(1, self._state(9.0))  # overwrite same step atomically
        r = mgr.restore(1, self._state(0.0))
        assert float(r["params"]["w"][0, 0]) == 9.0

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._state())
        bad = self._state()
        bad["params"]["w"] = jnp.zeros((2, 2))
        with pytest.raises(ValueError, match="shape"):
            mgr.restore(1, bad)
