import importlib.util
import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in
# a separate process).  Distributed tests spawn subprocesses with their own
# flags — see tests/test_distributed.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ``hypothesis`` is an *optional* dev dependency (requirements-dev.txt).  When
# absent, install the deterministic shim so property tests still run instead
# of erroring at collection.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
