import os

# Tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in
# a separate process).  Distributed tests spawn subprocesses with their own
# flags — see tests/test_distributed.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
