"""Tests for the compiler→kernel lowering pipeline (core/lowering.py).

Two layers: (1) *round-trip* — the block schedule ``lower_plan`` derives
must deliver exactly the operand sequence the AGU oracle
(``agu.gather_stream``) specifies; (2) *end-to-end* — a ``LoopNest`` fed
through ``ssrify()`` + ``ssr_call()`` executes as a Pallas kernel matching
the pure-jnp oracle.
"""

import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (BlockPolicy, Direction, LoopNest, LoweringError,
                        MemRef, agu, compiler, lower_nest, lower_plan,
                        plan_stats, ssr_call, ssrify)
from repro.core import lowering as L
from repro.kernels import ref

RNG = np.random.default_rng(7)


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


def delivered_elements(lowered, ls, operand):
    """Walk the grid in row-major order and concatenate the blocks the
    stream's index_map addresses — the operand sequence the core 'sees'."""
    prepared = np.asarray(ls.prepare(operand))
    br = ls.stream.block_shape[0]
    seq = []
    for g in itertools.product(*[range(d) for d in lowered.grid]):
        bi, bj = ls.stream.index_map(*g)
        seq.append(prepared[bi * br:(bi + 1) * br, :].reshape(-1))
    return np.concatenate(seq)


def logical_view(lowered, nest, flat):
    """Drop per-grid-step inner padding: (outer…, padded_inner) → valid."""
    padded_inner = lowered.steps // int(
        np.prod(nest.bounds[:-1], dtype=np.int64)) * lowered.policy.block_elems
    view = flat.reshape(*nest.bounds[:-1], padded_inner)
    return view[..., :nest.bounds[-1]].reshape(-1)


class TestRoundTrip:
    @pytest.mark.parametrize("n", [1024, 2048, 5000])
    def test_dot_streams_match_gather_oracle(self, n):
        nest = compiler.dot_product_nest(n)
        lowered = lower_plan(ssrify(nest))
        x, y = arr(n), arr(n)
        for ls, operand in zip(lowered.in_streams, (x, y)):
            got = logical_view(lowered, nest,
                               delivered_elements(lowered, ls, operand))
            want = np.asarray(agu.gather_stream(operand, ls.spec))
            np.testing.assert_array_equal(got, want)

    def test_2d_dense_and_repeat_streams(self):
        m, k = 4, 2048
        nest = LoopNest(
            bounds=(m, k),
            refs=(MemRef("A", Direction.READ, (k, 1)),
                  MemRef("v", Direction.READ, (0, 1))),
            compute_per_level=(0, 1))
        lowered = lower_plan(ssrify(nest))
        a = arr((m, k))
        v = arr(k)
        by_name = {ls.name: ls for ls in lowered.in_streams}
        got_a = logical_view(lowered, nest,
                             delivered_elements(lowered, by_name["A"], a))
        np.testing.assert_array_equal(
            got_a, np.asarray(agu.gather_stream(a, by_name["A"].spec)))
        # v is revisited per outer iteration — the repeat register; the
        # delivered sequence tiles v exactly like its AGU address walk.
        got_v = logical_view(lowered, nest,
                             delivered_elements(lowered, by_name["v"], v))
        np.testing.assert_array_equal(
            got_v, np.asarray(agu.gather_stream(v, by_name["v"].spec)))

    def test_grid_comes_from_block_grid(self):
        from repro.core import StreamSpec
        n = 4096
        lowered = lower_plan(ssrify(compiler.dot_product_nest(n)))
        E = lowered.policy.block_elems
        assert lowered.grid == agu.block_grid(
            StreamSpec(bounds=(n,), strides=(1,)), (E,))

    def test_policy_scales_grid(self):
        n = 8192
        small = lower_plan(ssrify(compiler.dot_product_nest(n)),
                           BlockPolicy(rows=4, lanes=128))
        big = lower_plan(ssrify(compiler.dot_product_nest(n)))
        assert small.grid[0] == 2 * big.grid[0]


class TestLoweringRejections:
    def test_strided_inner_walk_rejected_by_flat_path(self):
        # GEMM's B stream walks the innermost loop with stride n — fine for
        # the word-granular AGU and for the level-mapped lower_nest path
        # (see TestNestLowering), but not for the flattened 1-D schedule.
        with pytest.raises(LoweringError, match="unit-stride"):
            lower_plan(ssrify(compiler.gemm_nest(32, 32, 32), force=True))

    def test_non_dense_outer_rejected(self):
        nest = LoopNest(bounds=(4, 1024),
                        refs=(MemRef("A", Direction.READ, (2048, 1)),),
                        compute_per_level=(0, 1))
        with pytest.raises(LoweringError, match="dense row-major"):
            lower_plan(ssrify(nest, force=True))

    def test_unprofitable_plan_has_no_allocations(self):
        plan = ssrify(compiler.dot_product_nest(4))  # Eq. (3): too short
        assert not plan.ssrified
        with pytest.raises(LoweringError, match="no stream allocations"):
            lower_plan(plan)

    def test_unaligned_offset_rejected(self):
        # A varying stream whose base offset is not a whole number of
        # blocks cannot be served by whole-block DMA.
        nest = LoopNest(bounds=(2048,),
                        refs=(MemRef("A", Direction.READ, (1,), offset=64),),
                        compute_per_level=(1,))
        with pytest.raises(LoweringError, match="block-aligned"):
            lower_plan(ssrify(nest, force=True))


def _gemm_body(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


class TestNestLowering:
    """The level-mapped path: multi-level nests with contraction axes."""

    @pytest.mark.parametrize("mnk", [(32, 32, 32), (100, 130, 70),
                                     (4, 3, 5), (1, 1, 1)])
    def test_gemm_end_to_end_matches_dot(self, mnk):
        m, n, k = mnk
        a = jnp.asarray(RNG.standard_normal((m, k)) / np.sqrt(k),
                        jnp.float32)
        b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
        got = ssr_call(compiler.gemm_nest(m, n, k), _gemm_body,
                       {"A": a, "B": b})
        want = jnp.dot(a, b, preferred_element_type=jnp.float32)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gemm_multi_step_contraction_grid(self):
        # force a >1-step contraction walk so the accumulator's
        # init-on-first / drain-on-last actually carries across grid steps
        m, n, k = 64, 512, 1024
        lowered = lower_nest(ssrify(compiler.gemm_nest(m, n, k),
                                    num_lanes=3, force=True))
        assert lowered.grid[2] > 1
        assert lowered.semantics == ("parallel", "parallel", "arbitrary")
        a = jnp.asarray(RNG.standard_normal((m, k)) / np.sqrt(k),
                        jnp.float32)
        b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
        got = ssr_call(compiler.gemm_nest(m, n, k), _gemm_body,
                       {"A": a, "B": b})
        want = jnp.dot(a, b, preferred_element_type=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_coeff_level_lowers_to_invariant_index_map(self):
        # A's level-1 coefficient is 0: its index_map must ignore the n
        # grid axis (the repeat register at block granularity), and B must
        # likewise ignore m.
        lowered = lower_nest(ssrify(compiler.gemm_nest(64, 512, 256),
                                    num_lanes=3, force=True))
        by_name = {s.name: s for s in lowered.in_streams}
        for name, dead_axis in (("A", 1), ("B", 0)):
            _, coeffs = agu.affine_coefficients(
                by_name[name].stream.index_map, lowered.grid)
            assert all(int(x) == 0 for x in coeffs[dead_axis]), name

    def test_write_ref_storage_permutation(self):
        # B is stored (k, n) — a permutation of the (m, n, k) loop order
        lowered = lower_nest(ssrify(compiler.gemm_nest(8, 6, 4),
                                    num_lanes=3, force=True))
        by_name = {s.name: s for s in lowered.in_streams}
        assert by_name["B"].levels == (2, 1)
        assert by_name["B"].logical_shape == (4, 6)
        assert lowered.out_stream.levels == (0, 1)

    def test_non_trailing_contraction_rejected(self):
        # output varies with the innermost level but is revisited across a
        # *middle* level: the accumulator would drain mid-reduction
        nest = LoopNest(
            bounds=(4, 8, 16),
            refs=(MemRef("a", Direction.READ, (8 * 16, 16, 1)),
                  MemRef("o", Direction.WRITE, (16, 0, 1))),
            compute_per_level=(0, 1, 1))
        with pytest.raises(LoweringError, match="innermost"):
            lower_nest(ssrify(nest, num_lanes=2, force=True))

    def test_two_write_refs_rejected(self):
        nest = LoopNest(
            bounds=(64,),
            refs=(MemRef("x", Direction.READ, (1,)),
                  MemRef("u", Direction.WRITE, (1,)),
                  MemRef("v", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        with pytest.raises(LoweringError, match="write refs"):
            lower_nest(ssrify(nest, num_lanes=3, force=True))

    def test_unallocated_write_ref_rejected(self):
        # two lanes: deepest-first allocation spends both on A/B, the
        # output write gets no data mover
        plan = ssrify(compiler.gemm_nest(32, 32, 32), num_lanes=2,
                      force=True)
        with pytest.raises(LoweringError, match="not allocated a lane"):
            lower_nest(plan)

    def test_overlapping_walk_rejected(self):
        # stencil window x[i+j]: no dense storage order exists
        nest = LoopNest(
            bounds=(128, 11),
            refs=(MemRef("x", Direction.READ, (1, 1)),
                  MemRef("y", Direction.WRITE, (1, 0)),),
            compute_per_level=(0, 1))
        with pytest.raises(LoweringError, match="no dense"):
            lower_nest(ssrify(nest, num_lanes=2, force=True))

    def test_explicit_write_map_nest(self):
        # a write ref with no contraction axes: every step owns its block
        n = 3000
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("Y", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        x = arr(n)
        got = ssr_call(nest, lambda b: jnp.maximum(b, 0), {"X": x})
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.relu_ref(x)))

    def test_scalar_write_ref_is_full_contraction(self):
        # all-zero write coefficients: the dot product, write side included
        n = 4096
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("A", Direction.READ, (1,)),
                  MemRef("B", Direction.READ, (1,)),
                  MemRef("acc", Direction.WRITE, (0,))),
            compute_per_level=(1,))
        x, y = arr(n), arr(n)
        got = ssr_call(nest, lambda a, b: jnp.sum(a * b), {"A": x, "B": y})
        assert np.ndim(np.asarray(got)) == 0
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot_ref(x, y)),
                                   rtol=1e-4, atol=1e-3)

    def test_invariant_operand_must_fit_one_block(self):
        # a loop-invariant read serves exactly one (1, lanes) block; a
        # larger constant must error loudly, never silently truncate
        n = 2048
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("w", Direction.READ, (0,)),
                  MemRef("Y", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        with pytest.raises(ValueError, match="one .1, 128. block"):
            ssr_call(nest, lambda xb, wb: xb * wb[0, 0],
                     {"X": arr(n), "w": arr(300)})
        # a fitting constant works and honours its value
        got = ssr_call(nest, lambda xb, wb: xb * wb[0, 0],
                       {"X": arr(n), "w": jnp.full((1,), 3.0, jnp.float32)})
        assert got.shape == (n,)

    def test_invariant_operand_consumed_by_offset_rejected(self):
        # an offset past the end of the constant would serve pure padding
        n = 2048
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("w", Direction.READ, (0,), offset=64),
                  MemRef("Y", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        with pytest.raises(ValueError, match="no elements past offset"):
            ssr_call(nest, lambda xb, wb: xb * wb[0, 0],
                     {"X": arr(n), "w": arr(64)})

    def test_gemm_registry_kernel_rides_the_compiler(self):
        # the flagship: kernels/gemm.py ssr variant is a NestKernel —
        # cost model coverage comes with it
        stats = plan_stats(compiler.gemm_nest(32, 32, 32))
        assert stats.ssrified and stats.n_base > stats.n_ssr


class TestCacheUnification:
    """One CACHE_MAX across plan/chain/kernel caches; clear empties all."""

    def test_shared_sizing(self):
        assert L._KERNEL_CACHE_MAX == L.CACHE_MAX
        for c in L._PLAN_CACHES:
            # lru_cache exposes maxsize via cache_info
            assert c.cache_info().maxsize == L.CACHE_MAX

    def test_plan_cache_evicts_at_cache_max(self):
        L._plan_for.cache_clear()
        for n in range(L.CACHE_MAX + 32):
            L._plan_for(compiler.dot_product_nest(1024 + n), 2)
        info = L._plan_for.cache_info()
        assert info.currsize == L.CACHE_MAX  # eviction happened
        L._plan_for.cache_clear()

    def test_clear_caches_empties_every_cache(self):
        n = 2048
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        ssr_call(nest, lambda a, b: jnp.sum(a * b), {"A": x, "B": y})
        plan_stats(nest)
        from repro.kernels.chained import _chain_nests
        L._chain_for(_chain_nests(n, consumer_reads_w=False), None)
        assert L._plan_for.cache_info().currsize > 0
        assert plan_stats.cache_info().currsize > 0
        assert L._chain_for.cache_info().currsize > 0
        assert len(L._kernel_cache) > 0
        L.clear_caches()
        for c in L._PLAN_CACHES:
            assert c.cache_info().currsize == 0
        assert len(L._kernel_cache) == 0


class TestKernelCache:
    def _dot_once(self, n, body):
        nest = compiler.dot_product_nest(n)
        fixed = np.random.default_rng(21)
        x = jnp.asarray(fixed.standard_normal(n), jnp.float32)
        y = jnp.asarray(fixed.standard_normal(n), jnp.float32)
        return ssr_call(nest, body, {"A": x, "B": y})

    def test_inline_lambda_hits_cache(self):
        # the fixed footgun: a lambda re-created per call shares its code
        # object, so the second call must reuse the built kernel
        L._kernel_cache.clear()
        for _ in range(3):
            self._dot_once(2048, lambda a, b: jnp.sum(a * b))
        assert len(L._kernel_cache) == 1

    def test_closure_values_distinguish_kernels(self):
        # same code object, different (hashable) closure values: the cache
        # must NOT conflate them
        L._kernel_cache.clear()
        outs = []
        for scale in (1.0, 2.0):
            outs.append(self._dot_once(
                2048, lambda a, b: jnp.sum(a * b) * scale))
        assert len(L._kernel_cache) == 2
        np.testing.assert_allclose(2 * float(outs[0]), float(outs[1]),
                                   rtol=1e-5)

    def test_unhashable_closure_falls_back_to_identity(self):
        c = jnp.ones((1,), jnp.float32)  # arrays are unhashable
        body = lambda a, b: jnp.sum(a * b) + c[0]  # noqa: E731
        assert L._body_key(body) is body

    def test_bound_methods_distinguish_instances(self):
        # per-instance state lives on __self__, not in code/closure: two
        # instances' bound methods must not share a kernel
        class Body:
            def __init__(self, s):
                self.s = s

            def __call__(self, a, b):
                return jnp.sum(a * b) * self.s

            def method(self, a, b):
                return jnp.sum(a * b) * self.s

        assert L._body_key(Body(1.0).method) != L._body_key(Body(2.0).method)
        L._kernel_cache.clear()
        one = self._dot_once(2048, Body(1.0).method)
        two = self._dot_once(2048, Body(2.0).method)
        np.testing.assert_allclose(2 * float(one), float(two), rtol=1e-5)

    def test_kwonly_defaults_distinguish_kernels(self):
        def make(s):
            return lambda a, b, *, scale=s: jnp.sum(a * b) * scale

        assert L._body_key(make(1.0)) != L._body_key(make(2.0))

    def test_empty_closure_cell_falls_back(self):
        def outer():
            body = lambda a, b: late(a, b)  # noqa: E731, F821
            key = L._body_key(body)  # `late` cell still empty here
            late = lambda a, b: jnp.sum(a * b)  # noqa: E731, F841
            return body, key

        body, key = outer()
        assert key is body  # ValueError('Cell is empty') handled

    def test_lru_eviction_at_cache_max(self, monkeypatch):
        monkeypatch.setattr(L, "_KERNEL_CACHE_MAX", 2)
        L._kernel_cache.clear()
        bodies = [lambda a, b: jnp.sum(a * b),
                  lambda a, b: jnp.sum(a + b),
                  lambda a, b: jnp.sum(a - b)]
        keys = []
        for body in bodies:
            self._dot_once(2048, body)
            keys.append(next(reversed(L._kernel_cache)))
        assert len(L._kernel_cache) == 2
        # oldest entry evicted, newest two retained
        assert keys[0] not in L._kernel_cache
        assert keys[1] in L._kernel_cache and keys[2] in L._kernel_cache


class TestSsrCall:
    @pytest.mark.parametrize("n", [1024, 5000, 8192])
    def test_dot_product_end_to_end(self, n):
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        got = ssr_call(nest, lambda a, b: jnp.sum(a * b),
                       {"A": x, "B": y})
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot_ref(x, y)),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("n", [1024, 3000])
    def test_map_mode_elementwise(self, n):
        nest = LoopNest(bounds=(n,),
                        refs=(MemRef("X", Direction.READ, (1,)),),
                        compute_per_level=(1,))
        x = arr(n)
        got = ssr_call(nest, lambda a: jnp.maximum(a, 0), {"X": x},
                       mode="map")
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.relu_ref(x)))

    def test_2d_weighted_reduction(self):
        m, k = 4, 2048
        nest = LoopNest(
            bounds=(m, k),
            refs=(MemRef("A", Direction.READ, (k, 1)),
                  MemRef("v", Direction.READ, (0, 1))),
            compute_per_level=(0, 1))
        a, v = arr((m, k)), arr(k)
        got = ssr_call(nest, lambda ab, vb: jnp.sum(ab * vb),
                       {"A": a, "v": v})
        want = jnp.sum(a * v[None, :])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_invariant_stream_honours_offset(self):
        # A zero-coefficient operand with a base offset must deliver
        # data[offset], not data[0] (the AGU base-pointer shift).
        n = 2048
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("c", Direction.READ, (0,), offset=256)),
            compute_per_level=(1,))
        x = arr(n)
        c = arr(512)
        got = ssr_call(nest, lambda xb, cb: jnp.sum(xb) * cb[0, 0],
                       {"X": x, "c": c})
        want = jnp.sum(x) * c[256]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_matmul_ref_path_tolerates_tile_kwargs(self):
        # one call site must work under both ssrcfg states (§2.2.2)
        from repro.kernels import ops
        a, b = arr((16, 32)), arr((32, 16))
        got = ops.matmul(a, b, ssr=False, bm=16, bn=16, bk=32)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a @ b), rtol=1e-4, atol=1e-4)

    def test_missing_operand_raises(self):
        nest = compiler.dot_product_nest(2048)
        with pytest.raises(ValueError, match="missing operands"):
            ssr_call(nest, lambda a, b: jnp.sum(a * b), {"A": arr(2048)})

    def test_plan_cache_hits(self):
        nest = compiler.dot_product_nest(4096)
        L._plan_for.cache_clear()
        body = lambda a, b: jnp.sum(a * b)  # noqa: E731
        x, y = arr(4096), arr(4096)
        ssr_call(nest, body, {"A": x, "B": y})
        ssr_call(nest, body, {"A": x, "B": y})
        info = L._plan_for.cache_info()
        assert info.hits >= 1 and info.misses == 1

    def test_plan_stats_reports_static_verdict(self):
        stats = plan_stats(compiler.dot_product_nest(1000))
        assert stats.ssrified and stats.n_ssr == 1012
        short = plan_stats(compiler.dot_product_nest(3))
        assert not short.ssrified
