"""Tests for the compiler→kernel lowering pipeline (core/lowering.py).

Two layers: (1) *round-trip* — the block schedule ``lower_plan`` derives
must deliver exactly the operand sequence the AGU oracle
(``agu.gather_stream``) specifies; (2) *end-to-end* — a ``LoopNest`` fed
through ``ssrify()`` + ``ssr_call()`` executes as a Pallas kernel matching
the pure-jnp oracle.
"""

import itertools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (BlockPolicy, Direction, LoopNest, LoweringError,
                        MemRef, agu, compiler, lower_plan, plan_stats,
                        ssr_call, ssrify)
from repro.core import lowering as L
from repro.kernels import ref

RNG = np.random.default_rng(7)


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


def delivered_elements(lowered, ls, operand):
    """Walk the grid in row-major order and concatenate the blocks the
    stream's index_map addresses — the operand sequence the core 'sees'."""
    prepared = np.asarray(ls.prepare(operand))
    br = ls.stream.block_shape[0]
    seq = []
    for g in itertools.product(*[range(d) for d in lowered.grid]):
        bi, bj = ls.stream.index_map(*g)
        seq.append(prepared[bi * br:(bi + 1) * br, :].reshape(-1))
    return np.concatenate(seq)


def logical_view(lowered, nest, flat):
    """Drop per-grid-step inner padding: (outer…, padded_inner) → valid."""
    padded_inner = lowered.steps // int(
        np.prod(nest.bounds[:-1], dtype=np.int64)) * lowered.policy.block_elems
    view = flat.reshape(*nest.bounds[:-1], padded_inner)
    return view[..., :nest.bounds[-1]].reshape(-1)


class TestRoundTrip:
    @pytest.mark.parametrize("n", [1024, 2048, 5000])
    def test_dot_streams_match_gather_oracle(self, n):
        nest = compiler.dot_product_nest(n)
        lowered = lower_plan(ssrify(nest))
        x, y = arr(n), arr(n)
        for ls, operand in zip(lowered.in_streams, (x, y)):
            got = logical_view(lowered, nest,
                               delivered_elements(lowered, ls, operand))
            want = np.asarray(agu.gather_stream(operand, ls.spec))
            np.testing.assert_array_equal(got, want)

    def test_2d_dense_and_repeat_streams(self):
        m, k = 4, 2048
        nest = LoopNest(
            bounds=(m, k),
            refs=(MemRef("A", Direction.READ, (k, 1)),
                  MemRef("v", Direction.READ, (0, 1))),
            compute_per_level=(0, 1))
        lowered = lower_plan(ssrify(nest))
        a = arr((m, k))
        v = arr(k)
        by_name = {ls.name: ls for ls in lowered.in_streams}
        got_a = logical_view(lowered, nest,
                             delivered_elements(lowered, by_name["A"], a))
        np.testing.assert_array_equal(
            got_a, np.asarray(agu.gather_stream(a, by_name["A"].spec)))
        # v is revisited per outer iteration — the repeat register; the
        # delivered sequence tiles v exactly like its AGU address walk.
        got_v = logical_view(lowered, nest,
                             delivered_elements(lowered, by_name["v"], v))
        np.testing.assert_array_equal(
            got_v, np.asarray(agu.gather_stream(v, by_name["v"].spec)))

    def test_grid_comes_from_block_grid(self):
        from repro.core import StreamSpec
        n = 4096
        lowered = lower_plan(ssrify(compiler.dot_product_nest(n)))
        E = lowered.policy.block_elems
        assert lowered.grid == agu.block_grid(
            StreamSpec(bounds=(n,), strides=(1,)), (E,))

    def test_policy_scales_grid(self):
        n = 8192
        small = lower_plan(ssrify(compiler.dot_product_nest(n)),
                           BlockPolicy(rows=4, lanes=128))
        big = lower_plan(ssrify(compiler.dot_product_nest(n)))
        assert small.grid[0] == 2 * big.grid[0]


class TestLoweringRejections:
    def test_strided_inner_walk_rejected(self):
        # GEMM's B stream walks the innermost loop with stride n — fine for
        # the word-granular AGU, not expressible as whole-block DMA.
        with pytest.raises(LoweringError, match="unit-stride"):
            lower_plan(ssrify(compiler.gemm_nest(32, 32, 32), force=True))

    def test_non_dense_outer_rejected(self):
        nest = LoopNest(bounds=(4, 1024),
                        refs=(MemRef("A", Direction.READ, (2048, 1)),),
                        compute_per_level=(0, 1))
        with pytest.raises(LoweringError, match="dense row-major"):
            lower_plan(ssrify(nest, force=True))

    def test_unprofitable_plan_has_no_allocations(self):
        plan = ssrify(compiler.dot_product_nest(4))  # Eq. (3): too short
        assert not plan.ssrified
        with pytest.raises(LoweringError, match="no stream allocations"):
            lower_plan(plan)

    def test_unaligned_offset_rejected(self):
        # A varying stream whose base offset is not a whole number of
        # blocks cannot be served by whole-block DMA.
        nest = LoopNest(bounds=(2048,),
                        refs=(MemRef("A", Direction.READ, (1,), offset=64),),
                        compute_per_level=(1,))
        with pytest.raises(LoweringError, match="block-aligned"):
            lower_plan(ssrify(nest, force=True))


class TestKernelCache:
    def _dot_once(self, n, body):
        nest = compiler.dot_product_nest(n)
        fixed = np.random.default_rng(21)
        x = jnp.asarray(fixed.standard_normal(n), jnp.float32)
        y = jnp.asarray(fixed.standard_normal(n), jnp.float32)
        return ssr_call(nest, body, {"A": x, "B": y})

    def test_inline_lambda_hits_cache(self):
        # the fixed footgun: a lambda re-created per call shares its code
        # object, so the second call must reuse the built kernel
        L._kernel_cache.clear()
        for _ in range(3):
            self._dot_once(2048, lambda a, b: jnp.sum(a * b))
        assert len(L._kernel_cache) == 1

    def test_closure_values_distinguish_kernels(self):
        # same code object, different (hashable) closure values: the cache
        # must NOT conflate them
        L._kernel_cache.clear()
        outs = []
        for scale in (1.0, 2.0):
            outs.append(self._dot_once(
                2048, lambda a, b: jnp.sum(a * b) * scale))
        assert len(L._kernel_cache) == 2
        np.testing.assert_allclose(2 * float(outs[0]), float(outs[1]),
                                   rtol=1e-5)

    def test_unhashable_closure_falls_back_to_identity(self):
        c = jnp.ones((1,), jnp.float32)  # arrays are unhashable
        body = lambda a, b: jnp.sum(a * b) + c[0]  # noqa: E731
        assert L._body_key(body) is body

    def test_bound_methods_distinguish_instances(self):
        # per-instance state lives on __self__, not in code/closure: two
        # instances' bound methods must not share a kernel
        class Body:
            def __init__(self, s):
                self.s = s

            def __call__(self, a, b):
                return jnp.sum(a * b) * self.s

            def method(self, a, b):
                return jnp.sum(a * b) * self.s

        assert L._body_key(Body(1.0).method) != L._body_key(Body(2.0).method)
        L._kernel_cache.clear()
        one = self._dot_once(2048, Body(1.0).method)
        two = self._dot_once(2048, Body(2.0).method)
        np.testing.assert_allclose(2 * float(one), float(two), rtol=1e-5)

    def test_kwonly_defaults_distinguish_kernels(self):
        def make(s):
            return lambda a, b, *, scale=s: jnp.sum(a * b) * scale

        assert L._body_key(make(1.0)) != L._body_key(make(2.0))

    def test_empty_closure_cell_falls_back(self):
        def outer():
            body = lambda a, b: late(a, b)  # noqa: E731, F821
            key = L._body_key(body)  # `late` cell still empty here
            late = lambda a, b: jnp.sum(a * b)  # noqa: E731, F841
            return body, key

        body, key = outer()
        assert key is body  # ValueError('Cell is empty') handled

    def test_lru_eviction_at_cache_max(self, monkeypatch):
        monkeypatch.setattr(L, "_KERNEL_CACHE_MAX", 2)
        L._kernel_cache.clear()
        bodies = [lambda a, b: jnp.sum(a * b),
                  lambda a, b: jnp.sum(a + b),
                  lambda a, b: jnp.sum(a - b)]
        keys = []
        for body in bodies:
            self._dot_once(2048, body)
            keys.append(next(reversed(L._kernel_cache)))
        assert len(L._kernel_cache) == 2
        # oldest entry evicted, newest two retained
        assert keys[0] not in L._kernel_cache
        assert keys[1] in L._kernel_cache and keys[2] in L._kernel_cache


class TestSsrCall:
    @pytest.mark.parametrize("n", [1024, 5000, 8192])
    def test_dot_product_end_to_end(self, n):
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        got = ssr_call(nest, lambda a, b: jnp.sum(a * b),
                       {"A": x, "B": y})
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot_ref(x, y)),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("n", [1024, 3000])
    def test_map_mode_elementwise(self, n):
        nest = LoopNest(bounds=(n,),
                        refs=(MemRef("X", Direction.READ, (1,)),),
                        compute_per_level=(1,))
        x = arr(n)
        got = ssr_call(nest, lambda a: jnp.maximum(a, 0), {"X": x},
                       mode="map")
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.relu_ref(x)))

    def test_2d_weighted_reduction(self):
        m, k = 4, 2048
        nest = LoopNest(
            bounds=(m, k),
            refs=(MemRef("A", Direction.READ, (k, 1)),
                  MemRef("v", Direction.READ, (0, 1))),
            compute_per_level=(0, 1))
        a, v = arr((m, k)), arr(k)
        got = ssr_call(nest, lambda ab, vb: jnp.sum(ab * vb),
                       {"A": a, "v": v})
        want = jnp.sum(a * v[None, :])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_invariant_stream_honours_offset(self):
        # A zero-coefficient operand with a base offset must deliver
        # data[offset], not data[0] (the AGU base-pointer shift).
        n = 2048
        nest = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("c", Direction.READ, (0,), offset=256)),
            compute_per_level=(1,))
        x = arr(n)
        c = arr(512)
        got = ssr_call(nest, lambda xb, cb: jnp.sum(xb) * cb[0, 0],
                       {"X": x, "c": c})
        want = jnp.sum(x) * c[256]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_matmul_ref_path_tolerates_tile_kwargs(self):
        # one call site must work under both ssrcfg states (§2.2.2)
        from repro.kernels import ops
        a, b = arr((16, 32)), arr((32, 16))
        got = ops.matmul(a, b, ssr=False, bm=16, bn=16, bk=32)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a @ b), rtol=1e-4, atol=1e-4)

    def test_missing_operand_raises(self):
        nest = compiler.dot_product_nest(2048)
        with pytest.raises(ValueError, match="missing operands"):
            ssr_call(nest, lambda a, b: jnp.sum(a * b), {"A": arr(2048)})

    def test_plan_cache_hits(self):
        nest = compiler.dot_product_nest(4096)
        L._plan_for.cache_clear()
        body = lambda a, b: jnp.sum(a * b)  # noqa: E731
        x, y = arr(4096), arr(4096)
        ssr_call(nest, body, {"A": x, "B": y})
        ssr_call(nest, body, {"A": x, "B": y})
        info = L._plan_for.cache_info()
        assert info.hits >= 1 and info.misses == 1

    def test_plan_stats_reports_static_verdict(self):
        stats = plan_stats(compiler.dot_product_nest(1000))
        assert stats.ssrified and stats.n_ssr == 1012
        short = plan_stats(compiler.dot_product_nest(3))
        assert not short.ssrified
