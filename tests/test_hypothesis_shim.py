"""Shim-contract tests: the deterministic hypothesis stand-in vs the real
library.

``tests/conftest.py`` installs ``_hypothesis_shim`` as
``sys.modules["hypothesis"]`` only when the genuine package is absent, so
on a box with hypothesis installed the shim would otherwise go untested —
and vice versa.  This file closes the gap: one tiny property (the exact
strategy slice ``test_sparse.py`` leans on — ``integers``, ``sampled_from``,
``booleans``, ``lists(unique=...)``, ``composite``) runs under the shim
*loaded explicitly from its file*, and the same property runs again under
whatever ``import hypothesis`` resolves to.  When that resolves to the shim
(real library missing), the second run is skipped rather than duplicated.
"""

import importlib.util
import os

import pytest


def _load_shim():
    spec = importlib.util.spec_from_file_location(
        "_shim_under_test",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _real_hypothesis():
    """The installed hypothesis, or None when conftest swapped in the shim."""
    import hypothesis

    path = getattr(hypothesis, "__file__", "") or ""
    if path.endswith("_hypothesis_shim.py"):
        return None
    return hypothesis


def _run_contract_property(hyp, st):
    """The shared property: draws must respect bounds and uniqueness.

    Returns the number of executed examples so callers can assert the
    engine actually swept cases instead of passing vacuously.
    """
    executed = []

    @hyp.given(
        n=st.integers(min_value=1, max_value=8),
        dens=st.sampled_from([0.01, 0.1, 0.5]),
        flag=st.booleans(),
        cols=st.lists(st.integers(min_value=0, max_value=15),
                      min_size=0, max_size=10, unique=True),
    )
    def prop(n, dens, flag, cols):
        assert 1 <= n <= 8
        assert dens in (0.01, 0.1, 0.5)
        assert isinstance(flag, bool)
        assert all(0 <= c <= 15 for c in cols)
        assert len(set(cols)) == len(cols)
        executed.append(1)

    prop()
    return len(executed)


class TestShimContract:
    def test_property_under_shim(self):
        shim = _load_shim()
        assert _run_contract_property(shim, shim.strategies) >= 10

    def test_property_under_real_hypothesis(self):
        hyp = _real_hypothesis()
        if hyp is None:
            pytest.skip("real hypothesis not installed (shim active)")
        import hypothesis.strategies as st

        assert _run_contract_property(hyp, st) >= 10

    def test_unique_by_under_shim(self):
        shim = _load_shim()
        st = shim.strategies
        pairs = st.lists(
            st.tuples(st.integers(0, 3), st.integers(0, 100)),
            min_size=2, max_size=4, unique_by=lambda p: p[0])
        import random

        rng = random.Random(7)
        for _ in range(50):
            try:
                drawn = pairs.sample(rng)
            except shim._Assumption:
                continue  # bounded redraw exhausted: rejected, not hung
            keys = [p[0] for p in drawn]
            assert len(set(keys)) == len(keys)

    def test_shim_unsatisfiable_is_loud(self):
        shim = _load_shim()

        @shim.given(x=shim.strategies.integers(0, 10))
        def prop(x):
            shim.assume(False)

        with pytest.raises(AssertionError, match="rejected all"):
            prop()

    def test_shim_unique_exhaustion_rejects_sample(self):
        # 5 unique draws demanded from a 3-value space: every sample must
        # exhaust the redraw budget and reject — given() then raises its
        # Unsatisfiable mirror instead of looping forever or passing.
        shim = _load_shim()
        st = shim.strategies

        @shim.given(v=st.lists(st.integers(0, 2), min_size=5, max_size=5,
                               unique=True))
        def prop(v):
            raise AssertionError("unreachable: sample cannot be satisfied")

        with pytest.raises(AssertionError, match="rejected all"):
            prop()
