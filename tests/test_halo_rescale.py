"""Property-based differential tests for the §13 lowering patterns.

The halo-read lowering (windowed ``MemRef`` → ``2**k`` +1-shifted twin
streams, stitched in-kernel) and the online-rescaled accumulator
(``acc_kind="online_softmax"`` → flash m/l/acc VMEM recurrence) replaced
the hand-scheduled Launch paths of the whole stencil/attention family.
These tests sweep stencil widths and sizes (hand-built nests through
``ssr_call``, so the tap count is a free variable, not the kernels'
fixed diameter) and attention (seq, head) shapes — ragged and tiny
included — against plain-numpy oracles to ≤ 1e-5, and pin the loud
``LoweringError`` for a halo window wider than the block tile verbatim
(the message is API surface: it is the migration guide for the next
windowed kernel).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import LoweringError, compiler, ssr_call
from repro.core.lowering import Schedule
from repro.kernels import ref
from repro.kernels.attention import ssr_flash_attention
from repro.kernels.chained import fused_stencil1d_relu
from repro.kernels.stencil import TAPS, ssr_stencil1d, ssr_stencil2d

#: Differential-agreement bound (ISSUE acceptance): streamed halo /
#: rescaled paths vs plain-numpy oracles, both f32.
TOL = 1e-5


def _assert_close(got, want, tol=TOL):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (got.shape, want.shape)
    assert float(np.max(np.abs(got - want))) <= tol


def _tap_body(taps):
    """Generic fully-unrolled 1-D tap loop over a widened halo block."""

    def body(x_wide, w_blk):
        t = x_wide.shape[-1] - (taps - 1)
        acc = w_blk[0, 0] * x_wide[:, 0:t]
        for j in range(1, taps):
            acc = acc + w_blk[0, j] * x_wide[:, j:j + t]
        return acc

    return body


# --------------------------------------------------------------------------
# Halo reads — 1-D width sweep (hand-built nests: taps is free)
# --------------------------------------------------------------------------


class TestHaloStencil1D:
    @given(taps=st.integers(min_value=2, max_value=13),
           n=st.integers(min_value=1, max_value=400),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_width_sweep_matches_oracle(self, taps, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(n + taps - 1).astype(np.float32)
        w = (rng.standard_normal(taps) * 0.3).astype(np.float32)
        nest = compiler.stencil_nest(n, taps)
        got = ssr_call(nest, _tap_body(taps),
                       {"x": jnp.asarray(x), "w": jnp.asarray(w)})
        want = sum(w[j] * x[j:j + n] for j in range(taps))
        _assert_close(got, want)

    @given(n=st.integers(min_value=1, max_value=3000),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_public_kernel_ragged_sizes(self, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n + TAPS - 1), jnp.float32)
        w = jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)
        _assert_close(ssr_stencil1d(x, w), ref.stencil1d_ref(x, w))

    @given(n=st.integers(min_value=1, max_value=1500),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_fused_relu_consumer_rides_halo(self, n, seed):
        # the chained consumer reuses the producer's halo nest: same
        # shifted streams, relu applied in-VMEM before the write drains
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n + TAPS - 1), jnp.float32)
        w = jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)
        _assert_close(fused_stencil1d_relu(x, w),
                      np.maximum(np.asarray(ref.stencil1d_ref(x, w)), 0.0))


# --------------------------------------------------------------------------
# Halo reads — 2-D (2 halo'd levels → 4 shifted streams); H ≥ 9 so the
# sublane tile can cover the TAPS − 1 = 10 overlap columns
# --------------------------------------------------------------------------


class TestHaloStencil2D:
    @given(h=st.integers(min_value=9, max_value=80),
           wd=st.integers(min_value=1, max_value=80),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_shape_sweep_matches_oracle(self, h, wd, seed):
        r = TAPS // 2
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((h + 2 * r, wd + 2 * r)),
                        jnp.float32)
        wx = jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)
        wy = jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32)
        _assert_close(ssr_stencil2d(x, wx, wy), ref.stencil2d_ref(x, wx, wy))


class TestHaloWindowTooWide:
    """Satellite 2: the halo legality error is loud and pinned verbatim."""

    #: the exact message for an 11-point window over an 8-row grid (the
    #: sublane tile caps at the padded 8-row extent < 10 overlap columns)
    PINNED = ("stream 'x': halo window (11, 11) needs 10 overlap columns "
              "on level 0, but the block tile is only 8 wide; widen the "
              "tile so one block plus its +1-shifted neighbour covers the "
              "window")

    def _grid(self, h):
        r = TAPS // 2
        rng = np.random.default_rng(0)
        return (jnp.asarray(rng.standard_normal((h + 2 * r, 64 + 2 * r)),
                            jnp.float32),
                jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32),
                jnp.asarray(rng.standard_normal(TAPS) * 0.3, jnp.float32))

    def test_window_exceeding_tile_is_loud_and_verbatim(self):
        x, wx, wy = self._grid(8)      # padded rows extent 8 < TAPS - 1
        with pytest.raises(LoweringError) as exc:
            ssr_stencil2d(x, wx, wy)
        assert str(exc.value) == self.PINNED

    def test_boundary_height_lowers(self):
        x, wx, wy = self._grid(9)      # rounds up to a 16-row tile: legal
        _assert_close(ssr_stencil2d(x, wx, wy), ref.stencil2d_ref(x, wx, wy))


# --------------------------------------------------------------------------
# Online-rescaled accumulator — attention (seq, head) sweep
# --------------------------------------------------------------------------


@st.composite
def attention_shapes(draw):
    """(sq, sk, d, causal, window) with sk ≥ sq (causal rows stay
    non-empty under the decode-style query/key end alignment)."""
    sq = draw(st.integers(min_value=1, max_value=200))
    sk = sq + draw(st.integers(min_value=0, max_value=200))
    d = draw(st.sampled_from([4, 32, 64]))
    causal = draw(st.booleans())
    window = draw(st.sampled_from([None, 7, 64]))
    return sq, sk, d, causal, window


class TestOnlineRescaledAttention:
    @given(shape=attention_shapes(),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_shape_sweep_matches_oracle(self, shape, seed):
        sq, sk, d, causal, window = shape
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((sq, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((sk, d)), jnp.float32)
        _assert_close(
            ssr_flash_attention(q, k, v, causal=causal, window=window),
            ref.attention_ref(q, k, v, causal=causal, window=window))

    def test_schedule_invariance(self):
        # the m/l/acc recurrence must not depend on the kv tiling: the
        # rescale factor exp(m − m') re-normalises whatever the block
        # boundary was, so any legal schedule agrees to float error
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((192, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
        base = ssr_flash_attention(q, k, v, causal=True)
        for sched in (Schedule(buffer_depth=3), Schedule(rows=16),
                      Schedule(lanes_tile_factor=2)):
            got = ssr_flash_attention(q, k, v, causal=True, schedule=sched)
            _assert_close(got, base, tol=1e-6)
