"""Step-function contracts: grad accumulation invariance, prefill paths,
abstract state/caches, microbatch clamping."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import pipeline
from repro.launch import steps as SL
from repro.models import ModelConfig
from repro.models.config import ScanGroup
from repro.optim import adamw

KEY = jax.random.PRNGKey(3)
CFG = ModelConfig(name="s", family="dense", d_model=32, num_heads=2,
                  num_kv_heads=2, d_ff=64, vocab_size=64,
                  groups=(ScanGroup((("attn", "mlp"),), 2),), remat=False)
OPT = adamw.AdamWConfig(learning_rate=1e-3)


class TestTrainStep:
    def test_grad_accumulation_invariant(self):
        """microbatches=1 and =4 produce the same update (mean-of-means)."""
        dcfg = pipeline.DataConfig(global_batch=8, seq_len=16)
        batch = pipeline.make_batch(CFG, dcfg, 0)
        state = SL.init_train_state(KEY, CFG, OPT)
        p1, _, m1 = SL.make_train_step(CFG, OPT, microbatches=1)(
            state["params"], state["opt"], batch)
        p4, _, m4 = SL.make_train_step(CFG, OPT, microbatches=4)(
            state["params"], state["opt"], batch)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)

    def test_abstract_state_matches_concrete(self):
        abstract = SL.abstract_train_state(CFG, OPT)
        concrete = SL.init_train_state(KEY, CFG, OPT)
        fa = jax.tree.leaves(abstract)
        fc = jax.tree.leaves(concrete)
        assert len(fa) == len(fc)
        for a, c in zip(fa, fc):
            assert a.shape == c.shape and a.dtype == c.dtype


class TestPrefill:
    def test_chunked_matches_unchunked(self):
        params = SL.init_train_state(KEY, CFG, OPT)["params"]
        toks = jax.random.randint(KEY, (4, 24), 0, CFG.vocab_size)
        l1, c1 = SL.make_prefill_step(CFG, cache_len=32)(
            params, {"tokens": toks})
        l2, c2 = SL.make_prefill_step(CFG, cache_len=32, batch_chunks=2)(
            params, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)

    def test_prefill_then_decode(self):
        params = SL.init_train_state(KEY, CFG, OPT)["params"]
        toks = jax.random.randint(KEY, (2, 16), 0, CFG.vocab_size)
        logits, caches = SL.make_prefill_step(CFG, cache_len=24)(
            params, {"tokens": toks})
        assert logits.shape == (2, 1, CFG.vocab_size)
        serve = SL.make_decode_step(CFG)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, caches = serve(params, caches, nxt,
                                jnp.full((2,), 16, jnp.int32))
        assert logits2.shape == (2, 1, CFG.vocab_size)
        assert bool(jnp.isfinite(logits2).all())


class TestRooflineAnalysis:
    def test_model_flops(self):
        import sys
        sys.path.insert(0, ".")
        from benchmarks import roofline
        rec = {"kind": "train", "seq_len": 4096, "global_batch": 256,
               "arch": "yi_6b"}
        mf = roofline.model_flops_per_step("yi_6b", rec)
        # 6 · 6.06e9 · (4096·256 tokens) ≈ 3.8e16
        assert 3.5e16 < mf < 4.1e16

    def test_analyze_record(self):
        import sys
        sys.path.insert(0, ".")
        from benchmarks import roofline
        rec = {
            "status": "ok", "arch": "yi_6b", "shape": "train_4k",
            "mesh": "pod16x16", "kind": "train", "seq_len": 4096,
            "global_batch": 256,
            "memory": {"peak_per_device_gib": 10.0},
            "hlo": {"dot_flops_per_device": 197e12,      # 1 s compute
                    "bytes_out_per_device": 819e9 / 2,   # 0.5 s memory
                    "collective_bytes_per_device": 50e9 / 4,  # 0.25 s
                    "collective_counts": {}},
        }
        row = roofline.analyze_record("k", rec)
        assert row["dominant"] == "compute"
        assert row["t_compute_s"] == pytest.approx(1.0)
        # memory term uses the analytic HBM model; the HLO Σ-bytes walk is
        # kept as the recorded upper bound
        assert row["t_memory_upper_s"] == pytest.approx(0.5)
        assert 0 < row["t_memory_s"] < 0.5
        assert row["t_collective_s"] == pytest.approx(0.25)
        assert 0.5 < row["roofline_fraction"] <= 1.0
