"""Per-kernel shape/dtype sweeps: streamed Pallas (interpret) vs jnp oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BlockStream, Direction, ssr_pallas
from repro.kernels import ops, ref
from repro.kernels.gemm import baseline_matmul, ssr_matmul
from repro.kernels.gemv import baseline_gemv
from repro.kernels.reduction import baseline_dot
from repro.kernels.relu import baseline_relu
from repro.kernels.scan import baseline_scan
from repro.kernels.stencil import baseline_stencil1d

RNG = np.random.default_rng(42)


def arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestReduction:
    @pytest.mark.parametrize("n", [1024, 2048, 5000, 8192])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ssr_dot(self, n, dtype):
        x, y = arr(n, dtype), arr(n, dtype)
        got = ops.dot(x, y, ssr=True)
        want = ref.dot_ref(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-2 * np.sqrt(n) / 30)

    def test_baseline_matches(self):
        x, y = arr(2048), arr(2048)
        np.testing.assert_allclose(baseline_dot(x, y), ref.dot_ref(x, y),
                                   rtol=1e-4)


class TestScan:
    @pytest.mark.parametrize("n", [1024, 4096, 3000])
    def test_ssr_scan(self, n):
        x = arr(n)
        np.testing.assert_allclose(ops.prefix_sum(x, ssr=True),
                                   ref.scan_ref(x), rtol=1e-3, atol=1e-3)

    def test_baseline(self):
        x = arr(4096)
        np.testing.assert_allclose(baseline_scan(x), ref.scan_ref(x),
                                   rtol=1e-3, atol=1e-3)


class TestRelu:
    @pytest.mark.parametrize("n", [1024, 1025, 4096])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ssr_relu(self, n, dtype):
        x = arr(n, dtype)
        np.testing.assert_array_equal(np.asarray(ops.relu(x, ssr=True)),
                                      np.asarray(ref.relu_ref(x)))

    def test_baseline(self):
        x = arr(1024)
        np.testing.assert_array_equal(np.asarray(baseline_relu(x)),
                                      np.asarray(ref.relu_ref(x)))

    def test_integer_dtype_preserved_exactly(self):
        # regression: the compiled-nest engine must carry the storage
        # dtype end to end — 2**24 + 1 is not representable in f32, so a
        # float round-trip would silently lose the low bit
        from repro.kernels.relu import ssr_relu

        x = jnp.asarray([2**24 + 1, -(2**24 + 1), 7], jnp.int32)
        got = ssr_relu(x)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray([2**24 + 1, 0, 7]))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(baseline_relu(x)))


class TestStencil:
    @pytest.mark.parametrize("n", [1024, 512])
    def test_1d(self, n):
        x, w = arr(n + 10), arr(11, scale=0.3)
        np.testing.assert_allclose(ops.stencil1d(x, w, ssr=True),
                                   ref.stencil1d_ref(x, w),
                                   rtol=1e-3, atol=1e-4)

    def test_1d_baseline(self):
        x, w = arr(1034), arr(11, scale=0.3)
        np.testing.assert_allclose(baseline_stencil1d(x, w),
                                   ref.stencil1d_ref(x, w),
                                   rtol=1e-3, atol=1e-4)

    @pytest.mark.parametrize("hw", [(74, 74), (42, 74)])
    def test_2d(self, hw):
        x = arr(hw)
        wx, wy = arr(11, scale=0.3), arr(11, scale=0.3)
        np.testing.assert_allclose(ops.stencil2d(x, wx, wy, ssr=True),
                                   ref.stencil2d_ref(x, wx, wy),
                                   rtol=1e-3, atol=1e-3)


class TestGemv:
    @pytest.mark.parametrize("mn", [(64, 64), (128, 96), (60, 64)])
    def test_ssr(self, mn):
        a, x = arr(mn), arr(mn[1])
        np.testing.assert_allclose(ops.gemv(a, x, ssr=True),
                                   ref.gemv_ref(a, x), rtol=1e-3, atol=1e-3)

    def test_baseline(self):
        a, x = arr((64, 64)), arr(64)
        np.testing.assert_allclose(baseline_gemv(a, x), ref.gemv_ref(a, x),
                                   rtol=1e-3, atol=1e-3)


class TestGemm:
    @pytest.mark.parametrize("mnk", [(32, 32, 32), (256, 512, 384),
                                     (100, 130, 70)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ssr_matmul(self, mnk, dtype):
        m, n, k = mnk
        a, b = arr((m, k), dtype), arr((k, n), dtype)
        got = ssr_matmul(a, b, out_dtype=jnp.float32)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL[dtype])

    def test_block_reuse_reporting(self):
        """The A-panel repeat-register reuse shows up in the stream report."""
        a, b = arr((256, 256)), arr((256, 512))
        fn_out = ssr_matmul(a, b, bm=128, bn=128, bk=128)  # warm path
        assert fn_out.shape == (256, 512)

    @pytest.mark.parametrize("mnk", [(4, 3, 5), (1, 7, 2), (9, 200, 33),
                                     (130, 2, 257),
                                     # degenerate dims: column vector
                                     # (n=1), outer product (k=1), scalar
                                     (8, 1, 4), (4, 3, 1), (1, 1, 1)])
    def test_small_and_ragged_shapes(self, mnk):
        """Regression: tiny/ragged matrices must pad to min-clamped tiles,
        never up to a full production tile (the old `m % bm` re-block guard
        padded e.g. a 4-row matrix to a 256-row tile)."""
        m, n, k = mnk
        a, b = arr((m, k)), arr((k, n))
        got = ssr_matmul(a, b, out_dtype=jnp.float32)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.matmul_ref(a, b)),
                                   rtol=1e-5, atol=1e-5)

    def test_baseline(self):
        a, b = arr((64, 128)), arr((128, 64))
        np.testing.assert_allclose(np.asarray(baseline_matmul(a, b)),
                                   np.asarray(ref.matmul_ref(a, b)),
                                   rtol=2e-4, atol=2e-4)


class TestFFT:
    @pytest.mark.parametrize("n", [256, 1024, 2048])
    def test_ssr_fft(self, n):
        re, im = arr(n), arr(n)
        rr, ii = ops.fft(re, im, ssr=True)
        r0, i0 = ref.fft_ref(re, im)
        np.testing.assert_allclose(rr, r0, rtol=1e-3, atol=5e-2)
        np.testing.assert_allclose(ii, i0, rtol=1e-3, atol=5e-2)

    def test_non_power_of_two_rejected(self):
        with pytest.raises(ValueError):
            ops.fft(arr(100), arr(100), ssr=True)


class TestBitonic:
    @pytest.mark.parametrize("n", [64, 1024])
    def test_sorts(self, n):
        x = arr(n)
        np.testing.assert_array_equal(np.asarray(ops.sort(x, ssr=True)),
                                      np.sort(np.asarray(x)))

    def test_permutation_preserved(self):
        x = jnp.asarray(RNG.permutation(512).astype(np.float32))
        out = np.asarray(ops.sort(x, ssr=True))
        np.testing.assert_array_equal(out, np.arange(512, dtype=np.float32))


class TestAttention:
    @pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                               (True, 64)])
    @pytest.mark.parametrize("sq,sk", [(256, 256), (128, 256)])
    def test_vs_oracle(self, causal, window, sq, sk):
        q, k, v = arr((sq, 64)), arr((sk, 64)), arr((sk, 64))
        got = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  ssr=True)
        want = ref.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_vmap_heads(self):
        q, k, v = arr((4, 128, 32)), arr((4, 128, 32)), arr((4, 128, 32))
        got = jax.vmap(lambda a, b, c: ops.flash_attention(
            a, b, c, causal=True, ssr=True))(q, k, v)
        want = jax.vmap(lambda a, b, c: ref.attention_ref(
            a, b, c, causal=True))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestSSRPallasBuilder:
    def test_non_affine_index_map_rejected(self):
        def body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        with pytest.raises(ValueError, match="not affine"):
            ssr_pallas(
                body, grid=(4,),
                in_streams=[BlockStream((8, 128), lambda i: (i * i, 0))],
                out_streams=[BlockStream((8, 128), lambda i: (i, 0),
                                         Direction.WRITE)],
                out_shapes=[jax.ShapeDtypeStruct((32, 128), jnp.float32)],
            )

    def test_direction_enforced(self):
        def body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        with pytest.raises(ValueError, match="read stream"):
            ssr_pallas(
                body, grid=(1,),
                in_streams=[BlockStream((8, 128), lambda i: (0, 0),
                                        Direction.WRITE)],
                out_streams=[BlockStream((8, 128), lambda i: (0, 0),
                                         Direction.WRITE)],
                out_shapes=[jax.ShapeDtypeStruct((8, 128), jnp.float32)],
            )

    def test_stream_report_reuse(self):
        """GEMM A-panel: streamed bytes ≫ unique bytes (repeat register)."""
        def body(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        fn = ssr_pallas(
            body, grid=(2, 4),
            in_streams=[BlockStream((8, 128), lambda i, j: (i, 0), name="A")],
            out_streams=[BlockStream((8, 128), lambda i, j: (i, j),
                                     Direction.WRITE, name="O")],
            out_shapes=[jax.ShapeDtypeStruct((16, 512), jnp.float32)],
        )
        rep = fn.report(dtypes=[jnp.float32, jnp.float32])
        # A is fetched once per (i) but streamed 4× (reused across j)
        assert rep.reuse_factor > 1.5

    def test_vmem_budget_enforced(self):
        def body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        fn = ssr_pallas(
            body, grid=(1,),
            in_streams=[BlockStream((8192, 4096), lambda i: (0, 0))],
            out_streams=[BlockStream((8192, 4096), lambda i: (0, 0),
                                     Direction.WRITE)],
            out_shapes=[jax.ShapeDtypeStruct((8192, 4096), jnp.float32)],
        )
        with pytest.raises(ValueError, match="VMEM"):
            fn.report(dtypes=[jnp.float32, jnp.float32])
