"""Distributed behaviour on fake devices (subprocess: 8 host CPU devices).

These spawn fresh interpreters with ``--xla_force_host_platform_device_count``
so the main pytest process keeps its single device (dry-run contract).
Covered: sharded-vs-single-device train parity, compressed all-reduce,
elastic checkpoint resharding, sharding-policy divisibility.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestShardedTraining:
    def test_sharded_step_matches_single_device(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models import ModelConfig
            from repro.models.config import ScanGroup
            from repro.launch import steps as SL
            from repro.launch.mesh import make_host_mesh
            from repro.parallel import sharding as shd
            from repro.parallel.activations import activation_mesh
            from repro.data import pipeline
            from repro.optim import adamw

            cfg = ModelConfig(name="t", family="dense", d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                groups=(ScanGroup((("attn","mlp"),), 2),), remat=False)
            opt = adamw.AdamWConfig(learning_rate=1e-3)
            dcfg = pipeline.DataConfig(global_batch=8, seq_len=32)
            batch = pipeline.make_batch(cfg, dcfg, 0)
            state = SL.init_train_state(jax.random.PRNGKey(0), cfg, opt)
            train = SL.make_train_step(cfg, opt, microbatches=2)

            # single device reference
            p1, o1, m1 = jax.jit(train)(state["params"], state["opt"], batch)

            mesh = make_host_mesh(data=4, model=2)
            pspec = shd.param_spec_tree(
                jax.eval_shape(lambda: state["params"]), cfg, mesh)
            ospec = {"m": pspec, "v": pspec, "count": P()}
            bspec = {k: P("data") for k in batch}
            with mesh:
                with activation_mesh(mesh):
                    fn = jax.jit(train,
                        in_shardings=(shd.named(mesh, pspec),
                                      shd.named(mesh, ospec),
                                      shd.named(mesh, bspec)),
                        out_shardings=(shd.named(mesh, pspec),
                                       shd.named(mesh, ospec), None))
                    p8, o8, m8 = fn(state["params"], state["opt"], batch)
            assert abs(float(m1["loss"]) - float(m8["loss"])) < 2e-4, (
                float(m1["loss"]), float(m8["loss"]))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=3e-5, rtol=3e-4)
            print("PARITY OK", float(m1["loss"]))
        """)


class TestCompressedAllReduce:
    def test_compressed_psum_close_to_exact(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import make_host_mesh
            from repro.optim import compress

            mesh = make_host_mesh(data=8, model=1)
            g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

            def body(gs, rs):
                mean, new_r = compress.compressed_psum(
                    {"g": gs[0]}, {"g": rs[0]}, "data")
                return mean["g"][None], new_r["g"][None]

            f = shard_map(body, mesh=mesh,
                          in_specs=(P("data", None), P("data", None)),
                          out_specs=(P("data", None), P("data", None)))
            mean, resid = f(g, jnp.zeros_like(g))
            exact = jnp.mean(g, axis=0)
            got = np.asarray(mean[0])
            err = np.abs(got - np.asarray(exact)).max()
            scale = float(jnp.abs(g).max()) / 127.0
            assert err <= 2 * scale, (err, scale)
            # error feedback: residual holds the quantisation error
            assert float(jnp.abs(resid).max()) <= scale + 1e-6
            print("COMPRESS OK", err)
        """)


class TestElastic:
    def test_reshard_across_meshes(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp, tempfile
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.checkpoint.manager import CheckpointManager
            from repro.runtime.elastic import plan_rescale, restore_on_mesh
            from repro.models import ModelConfig
            from repro.models.config import ScanGroup
            from repro.launch import steps as SL
            from repro.launch.mesh import make_host_mesh
            from repro.optim import adamw
            import numpy as onp

            cfg = ModelConfig(name="t", family="dense", d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                groups=(ScanGroup((("attn","mlp"),), 2),), remat=False,
                microbatches=2)
            opt = adamw.AdamWConfig()
            state = SL.init_train_state(jax.random.PRNGKey(1), cfg, opt)
            d = tempfile.mkdtemp()
            mgr = CheckpointManager(d)
            mgr.save(5, state)

            devs = onp.array(jax.devices())
            big = make_host_mesh(data=4, model=2)
            small = Mesh(devs[:4].reshape(2, 2), ("data", "model"))
            plan = plan_rescale(cfg, 8, big, small)
            assert plan.microbatches >= cfg.microbatches, plan
            restored = restore_on_mesh(mgr, 5, state, cfg, small)
            # values preserved exactly, now placed on the 4-device mesh
            for a, b in zip(jax.tree.leaves(state["params"]),
                            jax.tree.leaves(restored["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            leaf = jax.tree.leaves(restored["params"])[0]
            assert len(leaf.sharding.device_set) <= 4
            print("ELASTIC OK", plan.note)
        """)


class TestShardingPolicy:
    def test_param_specs_divide_shapes(self):
        run_sub("""
            import jax
            from jax.sharding import PartitionSpec as P
            from repro import configs
            from repro.launch.mesh import make_host_mesh
            from repro.models import init_params
            from repro.parallel import sharding as shd
            import numpy as np

            mesh = make_host_mesh(data=2, model=4)
            for arch in ("yi_6b", "deepseek_v3_671b", "jamba_v01_52b"):
                cfg = configs.get(arch)
                shapes = jax.eval_shape(
                    lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
                specs = shd.param_spec_tree(shapes, cfg, mesh)
                flat_s = jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P))
                flat_x = jax.tree.leaves(shapes)
                assert len(flat_s) == len(flat_x)
                sharded = 0
                for spec, leaf in zip(flat_s, flat_x):
                    for dim, ax in zip(leaf.shape, tuple(spec)):
                        if ax is None:
                            continue
                        axes = ax if isinstance(ax, tuple) else (ax,)
                        n = int(np.prod([mesh.shape[a] for a in axes]))
                        assert dim % n == 0, (arch, leaf.shape, spec)
                        sharded += 1
                assert sharded > 10, arch  # policy actually shards things
            print("SPECS OK")
        """)


class TestRingMatmul:
    def test_ring_matches_plain(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from repro.launch.mesh import make_host_mesh
            from repro.parallel.collectives import (reduce_scatter_matmul,
                                                    ring_matmul)

            mesh = make_host_mesh(data=4, model=2)
            key = jax.random.PRNGKey(0)
            x = jax.random.normal(key, (16, 64), jnp.float32)
            w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32),
                                  jnp.float32)
            want = np.asarray(x @ w)
            got = np.asarray(jax.jit(
                lambda x, w: ring_matmul(x, w, mesh, axis="data",
                                         batch_axes=None))(x, w))
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
            got2 = np.asarray(jax.jit(
                lambda x, w: reduce_scatter_matmul(
                    x, w, mesh, axis="model"))(x, w))
            np.testing.assert_allclose(got2, want, rtol=2e-5, atol=2e-5)
            # grads flow through the ring
            g = jax.jit(jax.grad(lambda w: ring_matmul(
                x, w, mesh, axis="data").sum()))(w)
            assert bool(jnp.isfinite(g).all())
            print("RING OK")
        """)
