"""Tests for the §3.2 SSR-ification compiler pass."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Direction, LoopNest, MemRef, dot_product_nest,
                        gemm_nest, isa, ssrify)


class TestDotProduct:
    def test_fig4_plan(self):
        plan = ssrify(dot_product_nest(1000))
        assert plan.ssrified
        assert len(plan.allocations) == 2          # two data movers
        assert plan.n_ssr == 1012                  # Fig. 4 exact
        assert plan.n_base == 3001
        assert plan.speedup == pytest.approx(3001 / 1012)

    def test_short_loop_not_ssrified(self):
        # Eq. (3): 1-D needs more than 5 iterations
        assert not ssrify(dot_product_nest(5)).ssrified
        assert ssrify(dot_product_nest(6)).ssrified

    def test_force_overrides(self):
        plan = ssrify(dot_product_nest(2), force=True)
        assert plan.ssrified


class TestAllocation:
    def test_deepest_first(self):
        """With one lane, the deepest access wins (§3.2 step 3)."""
        nest = LoopNest(
            bounds=(8, 8),
            refs=(
                MemRef("outer", Direction.READ, (1, 0)),   # varies with i only
                MemRef("inner", Direction.READ, (8, 1)),   # varies with i,j
            ),
            compute_per_level=(0, 1),
        )
        plan = ssrify(nest, num_lanes=1)
        assert plan.ssrified
        assert plan.allocations[0].ref.name == "inner"
        assert any(r.name == "outer" for r in plan.residual)

    def test_non_affine_stays_explicit(self):
        nest = LoopNest(
            bounds=(64,),
            refs=(
                MemRef("a", Direction.READ, (1,)),
                MemRef("idx", Direction.READ, None),   # data-dependent
            ),
            compute_per_level=(1,),
        )
        plan = ssrify(nest)
        assert plan.ssrified
        names = [a.ref.name for a in plan.allocations]
        assert "idx" not in names
        assert any(r.name == "idx" for r in plan.residual)

    def test_nest_depth_limit(self):
        with pytest.raises(ValueError, match="AGU dims"):
            LoopNest(bounds=(2, 2, 2, 2, 2), refs=(),
                     compute_per_level=(0,) * 5)


class TestRepeatRegister:
    def test_trailing_zero_coeff_becomes_repeat(self):
        """A read reused across the innermost loop maps to `repeat` (§3.1)."""
        nest = LoopNest(
            bounds=(4, 8),
            refs=(MemRef("x", Direction.READ, (1, 0)),),  # constant in j
            compute_per_level=(0, 1),
        )
        plan = ssrify(nest, force=True)
        spec = plan.allocations[0].spec
        assert spec.repeat == 8
        assert spec.bounds == (4,)

    def test_gemm_streams(self):
        plan = ssrify(gemm_nest(32, 32, 32))
        assert plan.ssrified
        by_name = {a.ref.name: a.spec for a in plan.allocations}
        # A walks (m, k) and re-reads across n (stride-0 middle dim)
        assert by_name["A"].strides == (32, 0, 1)
        # B walks (n, k) independent of m
        assert by_name["B"].strides == (0, 1, 32)


class TestCostConsistency:
    @given(
        n=st.integers(1, 4096),
    )
    @settings(max_examples=60, deadline=None)
    def test_plan_matches_isa_model(self, n):
        plan = ssrify(dot_product_nest(n))
        if plan.ssrified:
            assert plan.n_ssr == isa.n_ssr([n], [1], 2)
            assert plan.n_ssr <= plan.n_base
        assert plan.n_base == isa.n_base([n], [1], 2)
