"""End-to-end behaviour: a short training run learns; serve loop generates."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.data import pipeline
from repro.launch import steps as SL
from repro.models import ModelConfig, decode_step, forward, init_caches
from repro.models.config import ScanGroup
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="e2e", family="dense", d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64,
                  groups=(ScanGroup((("attn", "mlp"),), 2),), remat=False)


def test_loss_decreases_over_training():
    opt = adamw.AdamWConfig(learning_rate=3e-3)
    dcfg = pipeline.DataConfig(global_batch=8, seq_len=32, seed=0)
    state = SL.init_train_state(KEY, CFG, opt)
    train = jax.jit(SL.make_train_step(CFG, opt, microbatches=1))
    losses = []
    params, opt_state = state["params"], state["opt"]
    for step in range(60):
        batch = pipeline.make_batch(CFG, dcfg, step)
        params, opt_state, metrics = train(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.3, (first, last)  # the Markov stream is learnable


def test_serve_generates_consistent_batch():
    """Batched prefill → decode loop; ragged per-sequence positions."""
    params = SL.init_train_state(KEY, CFG, adamw.AdamWConfig())["params"]
    B, S, T = 4, 24, 6
    toks = jax.random.randint(KEY, (B, S), 0, CFG.vocab_size)
    prefill = SL.make_prefill_step(CFG, cache_len=S + T)
    logits, caches = prefill(params, {"tokens": toks})
    assert logits.shape == (B, 1, CFG.vocab_size)
    serve = jax.jit(SL.make_decode_step(CFG))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [cur]
    for t in range(T - 1):
        pos = jnp.full((B,), S + t, jnp.int32)
        logits, caches = serve(params, caches, cur, pos)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(cur)
    gen = jnp.concatenate(outs, axis=1)
    assert gen.shape == (B, T)
    assert int(gen.max()) < CFG.vocab_size
    # decode trajectory must equal full-forward greedy continuation
    seq = toks
    for t in range(T):
        full, _, _ = forward(params, CFG, tokens=seq)
        nxt = jnp.argmax(full[:, -1:], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt[:, 0]),
                                      np.asarray(gen[:, t]))
        seq = jnp.concatenate([seq, nxt], axis=1)
