"""Chain DAGs: IR, refcounted scratch, fused lowering, cut search, uniforms.

Five layers, mirroring the whole-program fusion pipeline:

1. ``chain_dag()`` — dataflow discovery by name, multi-consumer edge
   accounting, and every DAG-specific ChainError (messages pinned verbatim);
2. ``_dag_slots()`` — refcounted VMEM slot assignment (a diamond takes 2
   slots, a linear chain 1);
3. ``ssr_dag_call()`` — fused execution vs the composition, every legal
   graph cut, and the uniform-operand contract (whole-array loop-invariant
   blocks);
4. the fusion search — legality, Eq. (1)–(3) cut costs, ``autotune_dag``
   commit → ``lookup_dag`` transparent resolution;
5. the bench artifacts — schema-v4 dag rows and the BENCH_history.jsonl
   appender/validator.
"""

import dataclasses
import re

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ChainError, Direction, LoopNest, LoweringError,
                        MemRef, chain, chain_dag, ssr_call, ssr_chain_call,
                        ssr_dag_call)
from repro.core import autotune
from repro.core import lowering as L
from repro.core.autotune import ScheduleCache
from repro.core.lowering import DEFAULT_SCHEDULE, Schedule

RNG = np.random.default_rng(21)


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


def _exact(msg: str) -> str:
    """Anchor an escaped literal so ``pytest.raises(match=...)`` pins the
    whole diagnostic, not a substring."""
    return "^" + re.escape(msg) + "$"


def _nest(n, reads, writes, compute=1):
    refs = tuple([MemRef(r, Direction.READ, (1,)) for r in reads]
                 + [MemRef(w, Direction.WRITE, (1,)) for w in writes])
    return LoopNest(bounds=(n,), refs=refs, compute_per_level=(compute,))


def diamond_nests(n):
    """X → T; T → U; (T, U) → out — the canonical multi-consumer shape."""
    return (_nest(n, ("X",), ("T",)),
            _nest(n, ("T",), ("U",)),
            _nest(n, ("T", "U"), (), compute=2))


DIAMOND_BODIES = (lambda xb: 2.0 * xb,
                  lambda tb: tb + 1.0,
                  lambda tb, ub: tb * ub)


def diamond_want(x):
    t = 2.0 * x
    return t * (t + 1.0)


# --------------------------------------------------------------------------
# 1. IR: chain_dag structure and accounting
# --------------------------------------------------------------------------


class TestChainDagIR:
    def test_diamond_edges(self):
        dag = chain_dag(diamond_nests(4096), force=True)
        assert [(e.name, e.producer_stage, e.consumer_stage)
                for e in dag.edges] == [("T", 0, 1), ("T", 0, 2),
                                        ("U", 1, 2)]
        assert dag.intermediates == ("T", "U")
        # body arg order of the join stage: (producer, name)-sorted
        assert [(e.name, e.producer_stage)
                for e in dag.in_edges(2)] == [("T", 0), ("U", 1)]
        assert dag.last_consumer("T") == 2
        assert dag.last_consumer("U") == 2

    def test_multi_consumer_accounting(self):
        n = 4096
        dag = chain_dag(diamond_nests(n), force=True)
        # T is written once but read twice: ONE eliminated store, TWO
        # eliminated loads — the credit linear chaining cannot express
        assert dag.eliminated_stores == 2 * n        # T, U
        assert dag.eliminated_loads == 3 * n         # per edge
        assert dag.eliminated_accesses == 5 * n
        assert dag.n_dag < dag.n_unfused
        assert dag.dag_speedup > 1.0
        # edge refs are stripped from every stage plan
        names = {a.ref.name for s in dag.stages for a in s.allocations}
        assert names == {"X"}

    def test_linear_chain_is_the_special_case(self):
        n = 4096
        nests = (_nest(n, ("X", "Y"), ("T",), compute=2),
                 _nest(n, ("T",), ()))
        cp = chain(nests, force=True)
        dp = chain_dag(nests, force=True)
        assert dp.eliminated_loads == cp.eliminated_loads
        assert dp.eliminated_stores == cp.eliminated_stores
        assert dp.n_dag == cp.n_chain
        assert dp.n_unfused == cp.n_unfused
        assert [(e.name, e.producer_stage, e.consumer_stage)
                for e in dp.edges] == [("T", 0, 1)]


class TestChainDagErrors:
    """Every DAG-specific ChainError path, message pinned verbatim."""

    def test_too_few_nests(self):
        with pytest.raises(ChainError,
                           match=_exact("chaining needs at least two nests")):
            chain_dag((_nest(64, ("X",), ("T",)),))

    def test_iteration_space_mismatch(self):
        with pytest.raises(ChainError, match=_exact(
                "stage 1 iteration space (2048,) != stage 0 (1024,); "
                "chained nests must share one iteration space")):
            chain_dag((_nest(1024, ("X",), ("T",)),
                       _nest(2048, ("T",), ())))

    def test_duplicate_writer(self):
        with pytest.raises(ChainError, match=_exact(
                "intermediate 'T' is written by both stage 0 and stage 1; "
                "each intermediate needs exactly one producer")):
            chain_dag((_nest(1024, ("X",), ("T",)),
                       _nest(1024, ("Y",), ("T",)),
                       _nest(1024, ("T",), ())))

    def test_read_before_write(self):
        with pytest.raises(ChainError, match=_exact(
                "stage 0 reads 'T' which stage 1 has not produced yet; "
                "stages must be listed in topological order (producers "
                "before consumers)")):
            chain_dag((_nest(1024, ("T",), ()),
                       _nest(1024, ("X",), ("T",))))

    def test_disconnected_stage(self):
        with pytest.raises(ChainError, match=_exact(
                "stage 1 is disconnected from the dag: no produced value "
                "links it to any other stage")):
            chain_dag((_nest(1024, ("X",), ("T",)),
                       _nest(1024, ("Y",), ()),
                       _nest(1024, ("T",), ())))

    def test_multiple_terminal_stages(self):
        with pytest.raises(ChainError, match=_exact(
                "stages [1, 2] all terminate the dag; exactly one final "
                "stage (the last) may produce the fused region's output")):
            chain_dag((_nest(1024, ("X",), ("T",)),
                       _nest(1024, ("T",), ()),
                       _nest(1024, ("T",), ())))

    def test_dead_intermediate(self):
        with pytest.raises(ChainError, match=_exact(
                "stage 0 writes 'D' but no later stage reads it; dead "
                "intermediates cannot leave the fused region")):
            chain_dag((_nest(1024, ("X",), ("T", "D")),
                       _nest(1024, ("T",), ())))


# --------------------------------------------------------------------------
# 2. Refcounted scratch slots
# --------------------------------------------------------------------------


class TestDagSlots:
    def test_diamond_needs_two_slots(self):
        dag = chain_dag(diamond_nests(2048), force=True)
        slot_of, n_slots = L._dag_slots(dag)
        assert n_slots == 2
        assert set(slot_of) == {"T", "U"}
        assert slot_of["T"] != slot_of["U"]   # both live into stage 2

    def test_linear_chain_reuses_one_slot(self):
        n = 2048
        nests = (_nest(n, ("X",), ("T",)),
                 _nest(n, ("T",), ("U",)),
                 _nest(n, ("U",), ("V",)),
                 _nest(n, ("V",), ()))
        dag = chain_dag(nests, force=True)
        slot_of, n_slots = L._dag_slots(dag)
        # each value dies at the stage that produces the next: one slot
        # cycles through the whole chain
        assert n_slots == 1
        assert set(slot_of.values()) == {0}

    def test_stage_writing_two_intermediates_rejected(self):
        dag = chain_dag((_nest(1024, ("X",), ("T", "U")),
                         _nest(1024, ("T", "U"), ())), force=True)
        with pytest.raises(LoweringError, match=_exact(
                "dag stage 0 produces intermediates ['T', 'U']; a stage "
                "body returns one block, so each non-final stage must "
                "write exactly one intermediate")):
            L._dag_slots(dag)


# --------------------------------------------------------------------------
# 3. Fused execution: numerics, cuts, linear equivalence, uniforms
# --------------------------------------------------------------------------


class TestSsrDagCall:
    @pytest.mark.parametrize("n", [1024, 5000])
    def test_diamond_map(self, n):
        x = arr(n)
        got = ssr_dag_call(diamond_nests(n), DIAMOND_BODIES, {"X": x},
                           mode="map")
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(diamond_want(x)),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [1024, 5000])
    def test_diamond_reduce(self, n):
        # padding-neutral by construction: x=0 → t=0 → t·(t+1)=0
        x = arr(n)
        got = ssr_dag_call(diamond_nests(n), DIAMOND_BODIES, {"X": x},
                           mode="reduce")
        want = float(jnp.sum(diamond_want(x)))
        np.testing.assert_allclose(float(got), want, rtol=1e-4, atol=1e-2)

    def test_every_legal_cut_matches_fused(self):
        n = 4096
        x = arr(n)
        nests = diamond_nests(n)
        dag = L._dag_for(nests, None)
        want = np.asarray(ssr_dag_call(nests, DIAMOND_BODIES, {"X": x},
                                       mode="map"))
        ran = 0
        for cut in autotune.enumerate_cuts(dag):
            if not autotune.dag_cut_is_legal(dag, cut)[0]:
                continue
            sched = dataclasses.replace(DEFAULT_SCHEDULE, cut_edges=cut)
            got = ssr_dag_call(nests, DIAMOND_BODIES, {"X": x},
                               mode="map", schedule=sched)
            np.testing.assert_allclose(np.asarray(got), want,
                                       rtol=1e-5, atol=1e-5)
            ran += 1
        assert ran >= 3   # (), the diamond split, and the full cut

    def test_linear_chain_matches_ssr_chain_call(self):
        n = 3000
        x, y = arr(n), arr(n)
        nests = (_nest(n, ("X", "Y"), ("T",), compute=2),
                 _nest(n, ("T",), ()))
        bodies = (lambda a, b: a - b, lambda t: jnp.maximum(t, 0.0))
        via_chain = ssr_chain_call(nests, bodies, {"X": x, "Y": y},
                                   mode="map")
        via_dag = ssr_dag_call(nests, bodies, {"X": x, "Y": y}, mode="map")
        # the DAG path lowers a 2-stage line to the same fused kernel the
        # linear path builds: identical blocks, identical op order —
        # bit-identical output, not merely close
        np.testing.assert_array_equal(np.asarray(via_dag),
                                      np.asarray(via_chain))


class TestUniformOperands:
    def test_whole_array_delivery_and_1d_reshape(self):
        n = 2048
        x, w = arr(n), arr(16)
        nest = _nest(n, ("X",), ())
        seen = []

        def body(xb, wb):
            seen.append(wb.shape)
            return xb * jnp.sum(wb)

        got = ssr_call(nest, body, {"X": x}, mode="map",
                       uniforms={"W": w})
        # 1-D uniforms gain a leading singleton (Pallas blocks are ≥ 2-D)
        assert all(s == (1, 16) for s in seen)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(x * jnp.sum(w)),
                                   rtol=1e-5, atol=1e-5)

    def test_scalar_uniform_rejected(self):
        nest = _nest(1024, ("X",), ())
        with pytest.raises(ValueError, match=_exact(
                "uniform 's' is a scalar; close over the Python value "
                "instead — scalar closures hash and cache fine")):
            ssr_call(nest, lambda xb, s: xb * s, {"X": arr(1024)},
                     mode="map", uniforms={"s": jnp.float32(2.0)})

    def test_level_mapped_path_rejected(self):
        nest = _nest(1024, ("X", "Y"), ("T",), compute=2)
        with pytest.raises(LoweringError, match=_exact(
                "uniform operands are not supported on the level-mapped "
                "(explicit WRITE ref) path; use a map/reduce nest")):
            ssr_call(nest, lambda a, b, w: a - b,
                     {"X": arr(1024), "Y": arr(1024)},
                     uniforms={"W": arr(128).reshape(1, -1)})

    def test_uniform_name_clash_rejected(self):
        with pytest.raises(ValueError, match=_exact(
                "uniform names ['X'] collide with streamed operands; "
                "uniforms are a separate argument namespace")):
            ssr_dag_call(diamond_nests(1024), DIAMOND_BODIES,
                         {"X": arr(1024)}, mode="map",
                         uniforms={"X": arr(16)})


# --------------------------------------------------------------------------
# 4. Schedule plumbing: asymmetric depths, JSON round-trip
# --------------------------------------------------------------------------


class TestStreamDepths:
    def test_asymmetric_depths_change_nothing_numerically(self):
        n = 4096
        x, y = arr(n), arr(n)
        nest = _nest(n, ("X", "Y"), (), compute=2)
        want = ssr_call(nest, lambda a, b: a * b, {"X": x, "Y": y},
                        mode="reduce")
        got = ssr_call(nest, lambda a, b: a * b, {"X": x, "Y": y},
                       mode="reduce",
                       schedule=Schedule(stream_depths=(4, 2)))
        np.testing.assert_allclose(float(got), float(want),
                                   rtol=1e-5, atol=1e-4)

    def test_wrong_depth_count_rejected(self):
        nest = _nest(1024, ("X", "Y"), (), compute=2)
        with pytest.raises(LoweringError, match=_exact(
                "schedule.stream_depths has 3 entries for 2 read streams; "
                "give one depth per stream (allocation order)")):
            ssr_call(nest, lambda a, b: a * b,
                     {"X": arr(1024), "Y": arr(1024)}, mode="reduce",
                     schedule=Schedule(stream_depths=(4, 2, 2)))

    def test_wrong_depth_count_is_illegal_schedule(self):
        nest = _nest(1024, ("X", "Y"), (), compute=2)
        legal, reason = autotune.schedule_is_legal(
            nest, Schedule(stream_depths=(2, 2, 2)))
        assert not legal
        assert "3 entries for 2 read streams" in reason

    def test_full_search_proposes_asymmetric_depths(self):
        nest = _nest(4096, ("X", "Y"), (), compute=2)
        full = autotune.candidate_schedules(nest, quick=False)
        asym = {s.stream_depths for s in full if s.stream_depths}
        assert {(4, 2), (2, 4), (3, 2), (2, 3)} <= asym
        # quick runs skip the per-stream sweep
        quick = autotune.candidate_schedules(nest, quick=True)
        assert not any(s.stream_depths for s in quick)

    def test_schedule_json_round_trip(self):
        for sched in (Schedule(stream_depths=(4, 2), cut_edges=()),
                      Schedule(cut_edges=(0, 2)),
                      Schedule(buffer_depth=3),
                      DEFAULT_SCHEDULE):
            assert Schedule.from_json(sched.to_json()) == sched
        # the () cut (all-fused, explicitly committed) must survive the
        # round trip distinct from None (never searched)
        assert Schedule.from_json(
            Schedule(cut_edges=()).to_json()).cut_edges == ()
        assert Schedule.from_json(
            DEFAULT_SCHEDULE.to_json()).cut_edges is None


# --------------------------------------------------------------------------
# 5. The fusion search
# --------------------------------------------------------------------------


class TestCutSearch:
    def test_enumerate_cuts_order(self):
        dag = L._dag_for(diamond_nests(1024), None)
        cuts = autotune.enumerate_cuts(dag)
        assert len(cuts) == 2 ** len(dag.edges)
        assert cuts[0] == ()
        assert cuts[-1] == tuple(range(len(dag.edges)))

    def test_diamond_legality(self):
        dag = L._dag_for(diamond_nests(1024), None)
        legal = [c for c in autotune.enumerate_cuts(dag)
                 if autotune.dag_cut_is_legal(dag, c)[0]]
        # a single severed edge leaves a component with two exit stages —
        # only the endpoints and the both-T-edges split survive
        assert legal == [(), (0, 1), (0, 1, 2)]

    def test_out_of_range_cut_index(self):
        dag = L._dag_for(diamond_nests(1024), None)
        legal, reason = autotune.dag_cut_is_legal(dag, (7,))
        assert not legal
        assert "out of range" in reason

    def test_model_cost_monotone_in_materialisation(self):
        dag = L._dag_for(diamond_nests(1024), None)
        fused = autotune.dag_model_cost(dag, ())
        split = autotune.dag_model_cost(dag, (0, 1))
        full = autotune.dag_model_cost(dag, (0, 1, 2))
        assert fused < split < full

    def test_autotune_commits_and_lookup_resolves(self, tmp_path):
        n = 2048
        x = arr(n)
        nests = diamond_nests(n)
        cache = ScheduleCache(path=str(tmp_path / "sched"))
        res = autotune.autotune_dag(nests, DIAMOND_BODIES, {"X": x},
                                    mode="map", cache=cache,
                                    warmup=0, iters=1, force=True)
        assert res.candidates == 3            # the legal diamond cuts
        assert res.measured == 3              # endpoints always race
        committed = cache.get(res.key)
        assert committed is not None
        assert committed.cut_edges == res.schedule.cut_edges
        # transparent dispatch: a later plain call resolves the same key
        assert autotune.lookup_dag(nests, {"X": x}, mode="map",
                                   cache=cache) == committed
        # and an un-tuned problem falls back to the default
        other = (_nest(n, ("X",), ("T",)), _nest(n, ("T",), ()))
        assert autotune.lookup_dag(other, {"X": x}, mode="map",
                                   cache=cache) == DEFAULT_SCHEDULE

    def test_cache_key_separates_uniforms(self):
        nests = diamond_nests(1024)
        x, w = arr(1024), arr(16).reshape(1, -1)
        k_plain = autotune.dag_cache_key(nests, {"X": x})
        k_uni = autotune.dag_cache_key(nests, {"X": x},
                                       uniforms={"W": w})
        assert k_plain != k_uni


# --------------------------------------------------------------------------
# 6. Registry DagCases: cut-path equivalence + HLO audit
# --------------------------------------------------------------------------


class TestDagRegistryKernels:
    def test_registered(self):
        from repro.kernels import registry
        for name in ("layernorm", "softmax_xent", "mlp_block"):
            entry = registry.get(name)
            assert entry.problem == f"fused DAG: {name}"
            assert entry.baseline is not None    # the unfused composition

    def test_layernorm_every_legal_cut(self):
        from repro.kernels.dag import dag_cases
        case = dag_cases()[0]
        args, kwargs = case.example(np.random.default_rng(3), odd=True)
        nests, bodies, operands, mode, uniforms = case.spec(*args, **kwargs)
        dag = L._dag_for(tuple(nests), None)
        want = np.asarray(case.ref(*args, **kwargs))
        for cut in autotune.enumerate_cuts(dag):
            if not autotune.dag_cut_is_legal(dag, cut)[0]:
                continue
            sched = dataclasses.replace(DEFAULT_SCHEDULE, cut_edges=cut)
            got = case.fused(*args, schedule=sched, **kwargs)
            np.testing.assert_allclose(np.asarray(got), want, **case.tol)

    def test_layernorm_hlo_audit(self):
        from repro.kernels.dag import dag_cases
        from repro.launch.hlo_analysis import check_dag_fusion
        case = dag_cases()[0]
        args, kwargs = case.example(np.random.default_rng(3))
        chk = check_dag_fusion(
            lambda *a, **k: case.fused(*a, schedule=DEFAULT_SCHEDULE, **k),
            case.unfused, args, kwargs, case.inters(*args, **kwargs))
        assert chk.intermediates_eliminated
        assert chk.bytes_saved > 0
        assert chk.fused_buffers <= chk.unfused_buffers


# --------------------------------------------------------------------------
# 7. Bench artifacts: schema-v4 dag rows + the run-history JSONL
# --------------------------------------------------------------------------


def _dag_row(kern, variant, value, cut, stages, **extra):
    from benchmarks.kernel_bench import _row
    return _row(f"dag/{kern}", "dag", variant, value, "us/call",
                cut_edges=list(cut), fused_stages=stages, **extra)


def _dag_trio(kern, cut_us, fused_us, unfused_us):
    return [_dag_row(kern, "cut", cut_us, (), 3, speedup=unfused_us / cut_us),
            _dag_row(kern, "fused", fused_us, (), 3),
            _dag_row(kern, "unfused", unfused_us, (0, 1, 2), 1)]


class TestBenchArtifacts:
    def test_schema_is_v6(self):
        from benchmarks import kernel_bench as kb
        assert kb.BENCH_SCHEMA == 6

    def test_validate_dag_rows_accepts_good_trios(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_dag_trio(k, 10.0, 10.0, 20.0)
                    for k in kb.DAG_GATED), [])
        kb.validate_dag_rows(rows)

    def test_validate_dag_rows_rejects_slow_cut(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_dag_trio(k, 10.0, 10.0, 20.0)
                    for k in kb.DAG_GATED[1:]), [])
        rows += _dag_trio(kb.DAG_GATED[0], 50.0, 10.0, 20.0)
        with pytest.raises(ValueError, match="slower than best endpoint"):
            kb.validate_dag_rows(rows)

    def test_validate_dag_rows_requires_partition_provenance(self):
        from benchmarks import kernel_bench as kb
        rows = sum((_dag_trio(k, 10.0, 10.0, 20.0)
                    for k in kb.DAG_GATED), [])
        del rows[0]["cut_edges"]
        with pytest.raises(ValueError, match="missing cut_edges"):
            kb.validate_dag_rows(rows)

    def test_validate_dag_rows_requires_all_kernels(self):
        from benchmarks import kernel_bench as kb
        rows = _dag_trio(kb.DAG_GATED[0], 10.0, 10.0, 20.0)
        with pytest.raises(ValueError, match="no complete dag rows"):
            kb.validate_dag_rows(rows)

    def test_history_round_trip(self, tmp_path):
        from benchmarks import kernel_bench as kb
        rows = sum((_dag_trio(k, 10.0, 10.0, 20.0)
                    for k in kb.DAG_GATED), [])
        path = str(tmp_path / "hist.jsonl")
        entry = kb.append_bench_history(rows, path, quick=True)
        assert entry["schema"] == kb.BENCH_SCHEMA
        assert entry["dag_cuts"] == {k: [] for k in kb.DAG_GATED}
        assert all(v == 2.0 for v in entry["speedups"].values())
        assert kb.validate_bench_history(path) == 1
        kb.append_bench_history(rows, path, quick=False)
        assert kb.validate_bench_history(path) == 2

    def test_history_rejects_corrupt_line(self, tmp_path):
        from benchmarks import kernel_bench as kb
        path = str(tmp_path / "hist.jsonl")
        kb.append_bench_history(_dag_trio("layernorm", 1.0, 1.0, 2.0),
                                path, quick=True)
        with open(path, "a") as f:
            f.write("{truncated\n")
        with pytest.raises(ValueError, match="not valid JSON"):
            kb.validate_bench_history(path)

    def test_history_rejects_missing_field(self, tmp_path):
        import json

        from benchmarks import kernel_bench as kb
        path = str(tmp_path / "hist.jsonl")
        kb.append_bench_history(_dag_trio("layernorm", 1.0, 1.0, 2.0),
                                path, quick=True)
        with open(path) as f:
            entry = json.loads(f.readline())
        del entry["git_sha"]
        with open(path, "a") as f:
            f.write(json.dumps(entry) + "\n")
        with pytest.raises(ValueError, match="missing/mistyped 'git_sha'"):
            kb.validate_bench_history(path)

    def test_history_rejects_empty(self, tmp_path):
        from benchmarks import kernel_bench as kb
        path = tmp_path / "hist.jsonl"
        path.write_text("\n")
        with pytest.raises(ValueError, match="empty history"):
            kb.validate_bench_history(str(path))
