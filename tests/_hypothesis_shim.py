"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite use a small slice of the hypothesis API:
``given``/``settings`` plus the ``integers``/``floats``/``lists``/``tuples``/
``sampled_from``/``booleans``/``composite``/``data`` strategies, including
``lists(..., unique=True)``/``unique_by`` (uniqueness via bounded redraw —
the sparse CSR strategies draw unique sorted column indices per row).  This
shim implements exactly that slice
with a seeded PRNG so the tests still sweep many pseudo-random cases — just
without shrinking, replay databases, or health checks.  ``tests/conftest.py``
installs it as ``sys.modules["hypothesis"]`` only when the real package is
missing; with hypothesis installed (see requirements-dev.txt) the genuine
library is used unchanged.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A sampler: ``sample(rng)`` draws one value."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _lists(elements, min_size=0, max_size=10, unique=False, unique_by=None):
    key = unique_by if unique_by is not None else (
        (lambda v: v) if unique else None)

    def sample(rng):
        k = rng.randint(min_size, max_size)
        if key is None:
            return [elements.sample(rng) for _ in range(k)]
        # uniqueness via bounded redraw — mirrors hypothesis semantics for
        # the small discrete element spaces this suite draws (e.g. CSR
        # column indices); an exhausted budget rejects the sample like a
        # failed assume() rather than looping forever
        out, seen = [], set()
        budget = 200 * max(1, k)
        while len(out) < k and budget:
            budget -= 1
            v = elements.sample(rng)
            kv = key(v)
            if kv in seen:
                continue
            seen.add(kv)
            out.append(v)
        if len(out) < min_size:
            raise _Assumption()
        return out

    return _Strategy(sample)


def _tuples(*elems):
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


class _Data:
    """Interactive draw object handed out by the ``data()`` strategy."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.sample(self._rng)


def _data():
    return _Strategy(_Data)


def _composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def build(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.sample(rng), *args, **kwargs)

        return _Strategy(sample)

    return build


class _Assumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Assumption()
    return True


def settings(*args, **kwargs):
    """Records ``max_examples`` for ``given``; every other knob is a no-op."""

    def deco(fn):
        fn._shim_settings = dict(kwargs)
        return fn

    return deco


class HealthCheck:  # attribute access only (settings(suppress_health_check=…))
    all = ()
    too_slow = None
    data_too_large = None
    filter_too_much = None


def given(*arg_strategies, **kw_strategies):
    if arg_strategies and kw_strategies:
        raise TypeError("shim: mixing positional and keyword strategies")

    def deco(fn):
        max_examples = getattr(fn, "_shim_settings", {}).get(
            "max_examples", DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # Seed from the test's qualified name: deterministic across runs,
            # distinct across tests.
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            executed = 0
            for _ in range(max_examples):
                try:
                    if arg_strategies:
                        drawn = [s.sample(rng) for s in arg_strategies]
                        fn(*args, *drawn, **kwargs)
                    else:
                        drawn = {k: s.sample(rng)
                                 for k, s in kw_strategies.items()}
                        fn(*args, **kwargs, **drawn)
                    executed += 1
                except _Assumption:
                    continue
            if executed == 0:
                # Mirror hypothesis's Unsatisfiable: a test whose assume()
                # rejected every sample must not pass vacuously.
                raise AssertionError(
                    f"shim: assume() rejected all {max_examples} examples "
                    f"for {fn.__qualname__}")

        # Hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis does the same): expose only the untouched ones.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if arg_strategies:
            params = params[: len(params) - len(arg_strategies)]
        else:
            params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.lists = _lists
strategies.tuples = _tuples
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans
strategies.data = _data
strategies.composite = _composite
