"""Stream chaining: compiler unification, fused lowering, HBM elimination.

Three layers, mirroring the pipeline:

1. ``chain()`` — structural unification and the extended Eq. (1)–(3) cost
   accounting (eliminated intermediate loads+stores);
2. ``lower_chain()`` / ``ssr_chain_call()`` — the fused single-kernel
   execution path, including the vectorised reduce accumulator;
3. the fused registry variants — numerics vs the unfused composition, and
   the compiled-HLO audit that the intermediate buffer is actually gone.
"""

import re

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ChainError, Direction, LoopNest, LoweringError,
                        MemRef, chain, lower_chain, ssr_call, ssr_chain_call)
from repro.core import lowering as L
from repro.kernels.chained import fused_cases
from repro.launch.hlo_analysis import check_fusion

RNG = np.random.default_rng(11)


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


def producer_nest(n, inter="T"):
    return LoopNest(
        bounds=(n,),
        refs=(MemRef("X", Direction.READ, (1,)),
              MemRef("Y", Direction.READ, (1,)),
              MemRef(inter, Direction.WRITE, (1,))),
        compute_per_level=(2,))


def consumer_nest(n, inter="T", **ref_kw):
    return LoopNest(
        bounds=(n,),
        refs=(MemRef(inter, Direction.READ, (1,), **ref_kw),),
        compute_per_level=(1,))


class TestChainCompiler:
    def test_cost_accounting(self):
        n = 5000
        cp = chain((producer_nest(n), consumer_nest(n)), force=True)
        # one fused setup beats two stand-alone setups
        assert cp.n_chain < cp.n_unfused
        # the headline quantity: one store + one load per element, gone
        assert cp.eliminated_loads == n
        assert cp.eliminated_stores == n
        assert cp.eliminated_accesses == 2 * n
        assert cp.chain_speedup > 1.0
        # the link refs are stripped from the per-stage plans
        names = {a.ref.name for s in cp.stages for a in s.allocations}
        assert "T" not in names
        assert names == {"X", "Y"}

    def test_needs_two_nests(self):
        with pytest.raises(ChainError, match="at least two"):
            chain((producer_nest(8),))

    def test_mismatched_iteration_spaces(self):
        with pytest.raises(ChainError, match="iteration space"):
            chain((producer_nest(1024), consumer_nest(2048)))

    def test_no_common_ref(self):
        with pytest.raises(ChainError, match="in common"):
            chain((producer_nest(1024, inter="T"),
                   consumer_nest(1024, inter="U")))

    def test_mismatched_walks_rejected(self):
        with pytest.raises(ChainError, match="cannot be unified"):
            chain((producer_nest(1024),
                   consumer_nest(1024, offset=128)))

    def test_three_stage_chain(self):
        n = 4096
        mid = LoopNest(
            bounds=(n,),
            refs=(MemRef("T", Direction.READ, (1,)),
                  MemRef("U", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        cp = chain((producer_nest(n), mid, consumer_nest(n, inter="U")),
                   force=True)
        assert len(cp.links) == 2
        assert cp.eliminated_accesses == 4 * n


def _exact(msg: str) -> str:
    """pytest.raises ``match`` pattern pinning the WHOLE message.

    ``match`` is ``re.search`` under the hood; anchoring an escaped literal
    turns it into an equality check, so a reworded diagnostic — the part of
    the compiler users actually debug with — fails tests instead of
    silently drifting.
    """
    return "^" + re.escape(msg) + "$"


class TestChainErrorMessages:
    """Every ``chain()`` ChainError path, message pinned verbatim."""

    def test_too_few_nests(self):
        with pytest.raises(ChainError,
                           match=_exact("chaining needs at least two nests")):
            chain((producer_nest(8),))

    def test_iteration_space_mismatch(self):
        with pytest.raises(ChainError, match=_exact(
                "stage 1 iteration space (2048,) != stage 0 (1024,); "
                "chained nests must share one iteration space")):
            chain((producer_nest(1024), consumer_nest(2048)))

    def test_no_common_intermediate(self):
        with pytest.raises(ChainError, match=_exact(
                "stages 0→1: need exactly one producer-write / "
                "consumer-read ref in common, found none")):
            chain((producer_nest(1024, inter="T"),
                   consumer_nest(1024, inter="U")))

    def test_multiple_common_intermediates(self):
        n = 1024
        prod = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("T", Direction.WRITE, (1,)),
                  MemRef("U", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        cons = LoopNest(
            bounds=(n,),
            refs=(MemRef("T", Direction.READ, (1,)),
                  MemRef("U", Direction.READ, (1,))),
            compute_per_level=(1,))
        with pytest.raises(ChainError, match=_exact(
                "stages 0→1: need exactly one producer-write / "
                "consumer-read ref in common, found ['T', 'U']")):
            chain((prod, cons))

    def test_non_affine_intermediate(self):
        gather = LoopNest(
            bounds=(1024,),
            refs=(MemRef("T", Direction.READ, None),),  # data-dependent
            compute_per_level=(1,))
        with pytest.raises(ChainError, match=_exact(
                "intermediate 'T' is not affine on both sides")):
            chain((producer_nest(1024), gather))

    def test_walk_mismatch(self):
        with pytest.raises(ChainError, match=_exact(
                "intermediate 'T': producer walk (1,)+0 != consumer walk "
                "(1,)+128; streams cannot be unified")):
            chain((producer_nest(1024),
                   consumer_nest(1024, offset=128)))


class TestLowerChain:
    def test_non_dense_link_rejected(self):
        n = 1024
        strided = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("T", Direction.WRITE, (2,))),
            compute_per_level=(1,))
        cons = LoopNest(
            bounds=(n,),
            refs=(MemRef("T", Direction.READ, (2,)),),
            compute_per_level=(1,))
        cp = chain((strided, cons), force=True)
        with pytest.raises(LoweringError, match="dense row-major walk"):
            lower_chain(cp)

    def test_extra_write_stream_rejected(self):
        n = 1024
        prod = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("S", Direction.WRITE, (1,)),   # survives stripping
                  MemRef("T", Direction.WRITE, (1,))),
            compute_per_level=(1,))
        cp = chain((prod, consumer_nest(n)), force=True)
        with pytest.raises(LoweringError, match="write streams"):
            lower_chain(cp)

    def test_unallocated_chain_rejected(self):
        # force=False on a too-short nest: every stage keeps the baseline
        cp = chain((producer_nest(3), consumer_nest(3)))
        with pytest.raises(LoweringError, match="no stream allocations"):
            lower_chain(cp)

    def test_grid_matches_single_plan_grid(self):
        from repro.core import lower_plan, ssrify
        n = 8192
        cp = chain((producer_nest(n), consumer_nest(n)), force=True)
        lc = lower_chain(cp)
        single = lower_plan(ssrify(
            LoopNest(bounds=(n,),
                     refs=(MemRef("X", Direction.READ, (1,)),),
                     compute_per_level=(1,)), force=True))
        assert lc.grid == single.grid
        assert lc.steps == single.steps

    def test_bad_stage_shape_rejected(self):
        n = 2048
        nests = (producer_nest(n), consumer_nest(n))
        with pytest.raises(LoweringError, match="VMEM block"):
            # producer body collapses the block to a scalar: not a linkable
            # intermediate
            ssr_chain_call(nests, (lambda a, b: jnp.sum(a * b),
                                   lambda t: t),
                           {"X": arr(n), "Y": arr(n)}, mode="reduce")

    def test_bad_final_map_shape_rejected(self):
        n = 2048
        nests = (producer_nest(n), consumer_nest(n))
        with pytest.raises(LoweringError, match="map-mode output"):
            # final map body must fill a block to feed the write stream
            ssr_chain_call(nests, (lambda a, b: a - b,
                                   lambda t: jnp.sum(t)),
                           {"X": arr(n), "Y": arr(n)}, mode="map")


class TestSsrChainCall:
    @pytest.mark.parametrize("n", [1024, 5000])
    def test_fused_reduce_matches_composition(self, n):
        x, y = arr(n), arr(n)
        nests = (producer_nest(n), consumer_nest(n))
        got = ssr_chain_call(
            nests, (lambda a, b: (a - b) * (a - b), lambda t: t),
            {"X": x, "Y": y}, mode="reduce")
        want = jnp.sum((x - y) ** 2)
        np.testing.assert_allclose(float(got), float(want),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("n", [1024, 3000])
    def test_fused_map(self, n):
        x, y = arr(n), arr(n)
        nests = (producer_nest(n), consumer_nest(n))
        got = ssr_chain_call(
            nests, (lambda a, b: a - b, lambda t: jnp.maximum(t, 0)),
            {"X": x, "Y": y}, mode="map")
        want = jnp.maximum(x - y, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_body_count_mismatch(self):
        nests = (producer_nest(1024), consumer_nest(1024))
        with pytest.raises(ValueError, match="one body per nest"):
            ssr_chain_call(nests, (lambda a, b: a - b,),
                           {"X": arr(1024), "Y": arr(1024)})

    def test_missing_operand(self):
        nests = (producer_nest(1024), consumer_nest(1024))
        with pytest.raises(ValueError, match="missing operands"):
            ssr_chain_call(nests, (lambda a, b: a - b, lambda t: t),
                           {"X": arr(1024)})

    def test_vector_accumulator_matches_scalar_path(self):
        # same reduction through the block-partial (vector acc) and the
        # scalar-partial (legacy (1,1) acc) contracts
        n = 5000
        x, y = arr(n), arr(n)
        nest = LoopNest(bounds=(n,),
                        refs=(MemRef("A", Direction.READ, (1,)),
                              MemRef("B", Direction.READ, (1,))),
                        compute_per_level=(1,))
        vec = ssr_call(nest, lambda a, b: a * b, {"A": x, "B": y})
        scal = ssr_call(nest, lambda a, b: jnp.sum(a * b), {"A": x, "B": y})
        np.testing.assert_allclose(float(vec), float(scal),
                                   rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("case", fused_cases(), ids=lambda c: c.name)
class TestFusedRegistryVariants:
    def test_numerics_match_unfused(self, case):
        for odd in (False, True):
            args, kwargs = case.example(np.random.default_rng(7), odd=odd)
            fused = case.fused(*args, **kwargs)
            unfused = case.unfused(*args, **kwargs)
            np.testing.assert_allclose(np.asarray(fused),
                                       np.asarray(unfused), **case.tol)
            np.testing.assert_allclose(np.asarray(fused),
                                       np.asarray(case.ref(*args, **kwargs)),
                                       **case.tol)

    def test_intermediate_hbm_buffer_eliminated(self, case):
        args, kwargs = case.example(np.random.default_rng(7))
        dtype, dims = case.inter_type(*args, **kwargs)
        chk = check_fusion(case.fused, case.unfused, args, kwargs,
                           dtype, dims)
        # <=, not <: the jitted prepare->finish dispatch lets XLA alias
        # away the unfused composition's copy buffers at some sizes, so
        # equal counts can coexist with the (still present) HBM hand-off;
        # the strict bytes_saved > 0 is what pins the eliminated
        # store+load (see FusionCheck's docstring).
        assert chk.fused_buffers <= chk.unfused_buffers, (
            f"{case.name}: fused program materialises MORE "
            f"{dtype}{list(dims)} buffers ({chk.fused_buffers}) than the "
            f"unfused composition ({chk.unfused_buffers})")
        assert chk.bytes_saved > 0
        assert chk.intermediate_eliminated
