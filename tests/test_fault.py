"""Fault tolerance: injected failures, checkpointed restart, determinism,
straggler detection.  The key property: a run interrupted by failures
produces EXACTLY the same final state as an uninterrupted run (checkpoint +
deterministic data stream ⇒ bit-identical replay)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.launch import steps as step_lib
from repro.models import ModelConfig
from repro.models.config import ScanGroup
from repro.optim import adamw
from repro.runtime.fault import (FailureInjector, SimulatedFailure,
                                 StragglerMonitor, Supervisor)

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="ft", family="dense", d_model=32, num_heads=2,
                  num_kv_heads=2, d_ff=64, vocab_size=64,
                  groups=(ScanGroup((("attn", "mlp"),), 1),), remat=False)
OPT = adamw.AdamWConfig(learning_rate=1e-3)
DCFG = pipeline.DataConfig(global_batch=2, seq_len=16, seed=1)


def make_step_fn():
    train = jax.jit(step_lib.make_train_step(CFG, OPT, microbatches=1))

    def step_fn(state, step):
        batch = pipeline.make_batch(CFG, DCFG, step)
        params, opt, metrics = train(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}

    return step_fn


def run(num_steps, fail_at=(), ckpt_dir=None, checkpoint_every=2):
    mgr = CheckpointManager(ckpt_dir, keep=3)
    sup = Supervisor(ckpt=mgr, checkpoint_every=checkpoint_every,
                     injector=FailureInjector(fail_at_steps=fail_at))
    state = step_lib.init_train_state(KEY, CFG, OPT)
    final = sup.run(state, make_step_fn(), num_steps)
    return final, sup


class TestRestart:
    def test_failure_recovery_is_exact(self, tmp_path):
        clean, _ = run(10, ckpt_dir=str(tmp_path / "a"))
        faulty, sup = run(10, fail_at=(3, 7), ckpt_dir=str(tmp_path / "b"))
        assert sup.restarts == 2
        assert any("restored@" in e for e in sup.events)
        for a, b in zip(jax.tree.leaves(clean["params"]),
                        jax.tree.leaves(faulty["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_failure_before_first_checkpoint(self, tmp_path):
        clean, _ = run(6, ckpt_dir=str(tmp_path / "a"))
        faulty, sup = run(6, fail_at=(1,), ckpt_dir=str(tmp_path / "b"))
        assert sup.restarts == 1
        for a, b in zip(jax.tree.leaves(clean["params"]),
                        jax.tree.leaves(faulty["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_too_many_failures_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        inj = FailureInjector(fail_at_steps=(2,))
        sup = Supervisor(ckpt=mgr, max_restarts=0, injector=inj)
        state = step_lib.init_train_state(KEY, CFG, OPT)
        with pytest.raises(SimulatedFailure):
            sup.run(state, make_step_fn(), 5)


class TestStraggler:
    def test_flags_outliers(self):
        mon = StragglerMonitor(threshold_sigma=3.0, warmup_steps=5)
        rng = np.random.default_rng(0)
        flagged = []
        for i in range(50):
            dt = 0.10 + rng.normal(0, 0.005)
            if i in (20, 40):
                dt = 0.5  # injected straggler
            if mon.observe(i, dt):
                flagged.append(i)
        assert 20 in flagged and 40 in flagged
        assert len(flagged) <= 4  # few false positives

    def test_supervisor_straggler_hook(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mon = StragglerMonitor(threshold_sigma=3.0, warmup_steps=3)
        hits = []
        # deterministic fake clock: advanced by the step function, so the
        # test cannot flake under host load
        fake = {"t": 0.0}
        sup = Supervisor(ckpt=mgr, straggler=mon,
                         on_straggler=hits.append, checkpoint_every=100,
                         clock=lambda: fake["t"])

        def slow_step(state, step):
            fake["t"] += 0.25 if step == 8 else 0.01
            return state

        sup.run({"x": jnp.zeros(())}, slow_step, 12)
        assert hits == [8]
