"""Model-stack behaviour: forward/grad finiteness, decode-vs-full parity,
ssrcfg on/off equivalence, MoE routing invariants, flash vs naive SDPA."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models import (ModelConfig, decode_step, forward, init_params,
                          loss_fn)
from repro.models.config import (MLAConfig, MambaConfig, MoEConfig, ScanGroup,
                                 XLSTMConfig)
from repro.models.flash import chunked_scan, flash_sdpa
from repro.models.moe import capacity, moe_apply

KEY = jax.random.PRNGKey(7)


def tiny(name, **kw):
    base = dict(
        name=name, family="dense", d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=97, groups=(ScanGroup((("attn", "mlp"),), 2),),
        head_dim=16, remat=False)
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny("dense", qk_norm=True),
    "swa": tiny("swa", window=24),
    "moe": tiny("moe", groups=(ScanGroup((("attn", "moe"),), 2),),
                moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                              num_shared=1, capacity_factor=2.0)),
    "mla": tiny("mla", num_kv_heads=4,
                groups=(ScanGroup((("mla", "mlp"),), 2),),
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)),
    "hybrid": tiny("hybrid",
                   groups=(ScanGroup((("mamba", "mlp"), ("attn", "mlp")), 1),),
                   mamba=MambaConfig(d_state=4)),
    "xlstm": tiny("xlstm", num_kv_heads=4, d_ff=0,
                  groups=(ScanGroup((("mlstm", "none"), ("slstm", "none")),
                                    1),),
                  xlstm=XLSTMConfig()),
}


@pytest.mark.parametrize("kind", list(CONFIGS))
class TestFamilies:
    def test_forward_grad(self, kind):
        cfg = CONFIGS[kind]
        params = init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        loss, metrics = loss_fn(params, cfg, batch)
        assert np.isfinite(float(loss))
        grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
        assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))

    def test_decode_matches_full(self, kind):
        cfg = CONFIGS[kind]
        params = init_params(KEY, cfg)
        B, S = 2, 48
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        logits, caches, _ = forward(params, cfg, tokens=toks,
                                    want_cache=True, cache_len=64)
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        step_logits, caches = decode_step(params, cfg, nxt, caches, pos)
        full2, _, _ = forward(params, cfg,
                              tokens=jnp.concatenate([toks, nxt], 1))
        err = float(jnp.max(jnp.abs(step_logits[:, 0] - full2[:, -1])))
        assert err < 5e-3, err

    def test_multi_step_decode(self, kind):
        """Greedy continuation via cache equals greedy via full re-forward."""
        cfg = CONFIGS[kind]
        params = init_params(KEY, cfg)
        B, S, T = 1, 24, 4
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        logits, caches, _ = forward(params, cfg, tokens=toks,
                                    want_cache=True, cache_len=S + T + 1)
        cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        seq = toks
        for t in range(T):
            seq = jnp.concatenate([seq, cur], axis=1)
            step_logits, caches = decode_step(
                params, cfg, cur, caches, jnp.full((B,), S + t, jnp.int32))
            full, _, _ = forward(params, cfg, tokens=seq)
            got = int(jnp.argmax(step_logits[0, 0]))
            want = int(jnp.argmax(full[0, -1]))
            assert got == want, f"step {t}: {got} != {want}"
            cur = jnp.array([[got]], jnp.int32)


class TestFlashEquivalence:
    @pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                               (True, 48)])
    def test_flash_matches_oracle(self, causal, window):
        B, S, H, KV, dh = 2, 256, 4, 2, 16
        q = jax.random.normal(KEY, (B, S, H, dh))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, dh))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, dh))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        got = flash_sdpa(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                         window=window, scale=0.25, bq=64, bk=64)
        for h in range(H):
            want = jax.vmap(lambda qq, kk, vv: ref.attention_ref(
                qq, kk, vv, causal=causal, window=window, scale=0.25))(
                q[:, :, h], k[:, :, h // (H // KV)], v[:, :, h // (H // KV)])
            np.testing.assert_allclose(np.asarray(got[:, :, h]),
                                       np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_chunked_scan_matches_plain(self):
        def step(c, x):
            c = 0.9 * c + x
            return c, c

        xs = jax.random.normal(KEY, (64, 8))
        c0 = jnp.zeros((8,))
        want_c, want_ys = jax.lax.scan(step, c0, xs)
        got_c, got_ys = chunked_scan(step, c0, xs, chunk=16, length=64)
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got_ys), np.asarray(want_ys),
                                   rtol=1e-6)


class TestMoE:
    def test_capacity_formula(self):
        m = MoEConfig(num_experts=4, top_k=2, d_expert=32,
                      capacity_factor=1.25)
        assert capacity(64, m) == 40  # ceil(64·2·1.25/4)=40

    def test_all_tokens_routed_when_capacity_ample(self):
        cfg = CONFIGS["moe"]
        params = init_params(KEY, cfg)
        x = jax.random.normal(KEY, (2, 16, cfg.d_model))
        moe_params = jax.tree.map(lambda p: p[0], params["groups"][0][0])["ffn"]
        y, aux = moe_apply(moe_params, x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())
        # Switch aux loss ≈ 1 at balance (hard counts vs soft probs may dip
        # slightly below the ideal bound)
        assert 0.85 <= float(aux) <= float(cfg.moe.num_experts)

    def test_expert_permutation_equivariance(self):
        """Permuting expert weights (and router cols) leaves output unchanged."""
        cfg = CONFIGS["moe"]
        params = init_params(KEY, cfg)
        moe_params = jax.tree.map(lambda p: p[0], params["groups"][0][0])["ffn"]
        x = jax.random.normal(KEY, (1, 8, cfg.d_model))
        y1, _ = moe_apply(moe_params, x, cfg)
        perm = jnp.array([2, 0, 3, 1])
        p2 = {
            "router": moe_params["router"][:, perm],
            "experts": jax.tree.map(lambda w: w[perm],
                                    moe_params["experts"]),
        }
        if "shared" in moe_params:
            p2["shared"] = moe_params["shared"]
        y2, _ = moe_apply(p2, x, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)


class TestSSRRegion:
    def test_region_toggles(self):
        from repro.core import ssr_enabled, ssr_region
        assert not ssr_enabled()
        with ssr_region():
            assert ssr_enabled()
            with ssr_region(False):
                assert not ssr_enabled()
            assert ssr_enabled()
        assert not ssr_enabled()

    def test_ops_equivalent_on_and_off(self):
        """ssrcfg=1 and ssrcfg=0 execute identical semantics (§2.2.2)."""
        from repro.core import ssr_region
        from repro.kernels import ops
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal(2048), jnp.float32)
        y = jnp.asarray(rng.standard_normal(2048), jnp.float32)
        with ssr_region():
            on = [ops.dot(x, y), ops.prefix_sum(x), ops.relu(x)]
        off = [ops.dot(x, y), ops.prefix_sum(x), ops.relu(x)]
        for a, b in zip(on, off):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-3)
