"""Cluster layer (paper §5.3–5.5): partitioning, cost model, execution.

In-process tests cover the degenerate single-core path (``cores=1`` must
bypass the mesh — the main pytest process keeps exactly one device) and the
pure-Python cost model.  Multi-core execution spawns a fresh interpreter
with 8 forced host devices, like tests/test_distributed.py: every registry
kernel with a ``cluster`` variant must match its single-core streamed
output, and the compiled HLO must show per-core intermediates staying
core-local (one all-reduce for reduces, none for maps).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compiler
from repro.core.compiler import (ClusterReport, cluster_cost,
                                 iso_performance_cores)
from repro.core.lowering import ssr_call

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# --------------------------------------------------------------------------
# Cost model (pure python — no devices needed)
# --------------------------------------------------------------------------


class TestClusterCost:
    def test_one_core_is_the_single_core_plan(self):
        nest = compiler.dot_product_nest(2048)
        rep = cluster_cost(nest, 1)
        assert isinstance(rep, ClusterReport)
        assert rep.combine == 0
        assert rep.n_cluster == rep.n_single
        assert rep.speedup == 1.0
        plan = compiler.ssrify(nest, num_lanes=2, force=True)
        assert rep.n_single == plan.n_ssr

    def test_speedup_increases_with_cores(self):
        nest = compiler.dot_product_nest(2048)
        reps = [cluster_cost(nest, c) for c in (1, 2, 4, 8)]
        speeds = [r.speedup for r in reps]
        assert all(b > a for a, b in zip(speeds, speeds[1:])), speeds
        # utilization decays as per-core setup + combine amortise less
        etas = [r.eta_cluster for r in reps]
        assert all(b < a for a, b in zip(etas, etas[1:])), etas
        assert all(0.0 < e <= 1.0 for e in etas)

    def test_ragged_split_keeps_all_work(self):
        nest = compiler.dot_product_nest(10)
        rep = cluster_cost(nest, 4)  # ceil tiles: 3,3,3,1
        extents = [c.bounds[0] for c in rep.per_core]
        assert extents == [3, 3, 3, 1]
        assert sum(c.compute for c in rep.per_core) == 10

    def test_idle_cores_counted_against_eta(self):
        nest = compiler.dot_product_nest(8)
        rep = cluster_cost(nest, 8)
        assert all(c.bounds[0] == 1 for c in rep.per_core)
        rep_over = cluster_cost(compiler.dot_product_nest(4), 8)
        idle = [c for c in rep_over.per_core if c.n == 0]
        assert len(idle) == 4
        assert rep_over.eta_cluster < cluster_cost(
            compiler.dot_product_nest(4), 4).eta_cluster

    def test_chain_cost_scales_eliminated_accesses(self):
        from repro.kernels.chained import _chain_nests

        nests = _chain_nests(4096, consumer_reads_w=False)
        r1 = cluster_cost(nests, 1)
        r4 = cluster_cost(nests, 4)
        assert r1.chained and r4.chained
        # every element's store+load is eliminated regardless of the split
        assert r1.eliminated_accesses == r4.eliminated_accesses == 2 * 4096
        assert r4.speedup > r1.speedup

    def test_fetches_and_bytes(self):
        nest = compiler.dot_product_nest(2048)
        rep = cluster_cost(nest, 4)
        # two f32 streams of 2048 elements, split across cores
        assert rep.bytes_moved == 2 * 2048 * 4
        assert rep.total_fetches == sum(c.n for c in rep.per_core) \
            + 4 * rep.combine

    def test_iso_performance_beats_baseline_cores(self):
        nest = compiler.dot_product_nest(2048)
        for base_c in (2, 4, 6, 8):
            iso = iso_performance_cores(nest, base_c)
            assert iso < base_c, (base_c, iso)
        # the paper's headline point: ~3x fewer cores at 6 baseline cores
        assert iso_performance_cores(nest, 6) == 2


# --------------------------------------------------------------------------
# Degenerate C=1 path (single device, in-process)
# --------------------------------------------------------------------------


class TestSingleCoreDegenerate:
    def test_cores1_identical_to_ssr_call(self):
        from repro.parallel.cluster import cluster_call

        rng = np.random.default_rng(0)
        n = 2048
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        y = jnp.asarray(rng.standard_normal(n), jnp.float32)
        nest = compiler.dot_product_nest(n)
        body = lambda a, b: a * b  # noqa: E731
        got = cluster_call(nest, body, {"A": x, "B": y}, cores=1,
                           mode="reduce")
        want = ssr_call(nest, body, {"A": x, "B": y}, mode="reduce")
        assert float(got) == float(want)  # same code path, bit-identical

    def test_cores1_registry_variants_match_ssr(self):
        from repro.kernels import registry

        rng = np.random.default_rng(1)
        for name in registry.names():
            entry = registry.get(name)
            if entry.cluster is None or entry.example is None:
                continue
            args, kwargs = entry.example(rng)
            got = entry.cluster(*args, cores=1, **kwargs)
            want = entry.ssr(*args, **kwargs)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=name)

    def test_multi_core_without_devices_raises(self):
        from repro.parallel.cluster import ClusterError, cluster_call

        nest = compiler.dot_product_nest(64)
        x = jnp.ones(64, jnp.float32)
        with pytest.raises(ClusterError, match="device"):
            cluster_call(nest, lambda a, b: a * b, {"A": x, "B": x},
                         cores=2, mode="reduce")

    def test_indivisible_outer_bound_raises(self):
        from repro.parallel.cluster import ClusterError, _split_level0

        with pytest.raises(ClusterError, match="not divisible"):
            _split_level0(compiler.dot_product_nest(10), 4)

    def test_bad_mode_and_cores_raise(self):
        from repro.parallel.cluster import ClusterError, cluster_call

        nest = compiler.dot_product_nest(64)
        x = jnp.ones(64, jnp.float32)
        with pytest.raises(ClusterError, match="mode"):
            cluster_call(nest, lambda a: a, {"A": x}, cores=1, mode="scanz")
        with pytest.raises(ClusterError, match=">= 1"):
            cluster_call(nest, lambda a: a, {"A": x}, cores=0, mode="map")


# --------------------------------------------------------------------------
# Multi-core execution (subprocess: 8 forced host devices)
# --------------------------------------------------------------------------


class TestShardedExecution:
    def test_registry_cluster_variants_match_single_core(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from repro.kernels import registry

            rng = np.random.default_rng(0)
            checked = 0
            for name in registry.names():
                entry = registry.get(name)
                if entry.cluster is None or entry.example is None:
                    continue
                args, kwargs = entry.example(rng)
                single = np.asarray(entry.ssr(*args, **kwargs))
                for cores in (2, 4, 8):
                    out = np.asarray(entry.cluster(*args, cores=cores,
                                                   **kwargs))
                    np.testing.assert_allclose(
                        out, single, rtol=1e-5, atol=1e-5,
                        err_msg=f"{name} cores={cores}")
                checked += 1
            assert checked >= 3, checked
            print("CLUSTER AGREE OK", checked)
        """)

    def test_locality_and_odd_sizes(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from repro.core import compiler
            from repro.kernels import registry
            from repro.launch.hlo_analysis import check_cluster_locality
            from repro.parallel.cluster import cluster_call

            rng = np.random.default_rng(0)

            # reduce-mode cluster call: exactly one all-reduce (the psum)
            red = registry.get("reduction")
            args, kwargs = red.example(rng)
            chk = check_cluster_locality(
                lambda *a: red.cluster(*a, cores=4, **kwargs), args,
                mode="reduce", world=4)
            assert chk.ok, chk.counts

            # map-mode: per-core tiles stay local, zero collectives
            rel = registry.get("relu")
            args, kwargs = rel.example(rng)
            chk = check_cluster_locality(
                lambda *a: rel.cluster(*a, cores=4, **kwargs), args,
                mode="map", world=4)
            assert chk.ok, chk.counts

            # odd (non-multiple-of-cores) sizes route through the padding
            # in the kernel wrappers
            for name in ("reduction", "relu", "gemv", "sum_sq_diff",
                         "axpy_dot"):
                entry = registry.get(name)
                args, kwargs = entry.example(rng, odd=True)
                single = np.asarray(entry.ssr(*args, **kwargs))
                out = np.asarray(entry.cluster(*args, cores=8, **kwargs))
                np.testing.assert_allclose(out, single, rtol=1e-5,
                                           atol=1e-5, err_msg=name)

            # ClusterError on an indivisible operand fed straight to
            # cluster_call (no wrapper padding)
            from repro.parallel.cluster import ClusterError
            nest = compiler.dot_product_nest(100)
            x = jnp.ones(100, jnp.float32)
            try:
                cluster_call(nest, lambda a, b: a * b, {"A": x, "B": x},
                             cores=8, mode="reduce")
            except ClusterError as e:
                assert "divisible" in str(e)
            else:
                raise AssertionError("expected ClusterError")
            print("CLUSTER LOCALITY OK")
        """)
