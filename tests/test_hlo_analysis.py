"""HLO walker correctness: trip counts, dot FLOPs, collective traffic."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def lower_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestShapes:
    def test_shape_bytes(self):
        b, e = H._shape_bytes_elems("bf16[128,256]{1,0}")
        assert e == 128 * 256
        assert b == 128 * 256 * 2

    def test_tuple_types(self):
        b, e = H._shape_bytes_elems("(f32[8,8]{1,0}, s32[4]{0})")
        assert b == 8 * 8 * 4 + 4 * 4


class TestTripCounts:
    @pytest.mark.parametrize("r", [3, 7, 16])
    def test_scan_flops_scale_with_trip_count(self, r):
        def f(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()

        w = jnp.ones((r, 64, 64))
        x = jnp.ones((8, 64))
        an = H.analyze_hlo(lower_text(f, w, x), world=1)
        want_dot = r * 2 * 8 * 64 * 64
        assert an.dot_flops == pytest.approx(want_dot, rel=0.01), (
            r, an.dot_flops, an.while_trips)
        assert r in an.while_trips

    def test_nested_scans_multiply(self):
        def f(x):
            def outer(c, _):
                def inner(ci, _):
                    return jnp.tanh(ci @ ci), None
                ci, _ = jax.lax.scan(inner, c, None, length=5)
                return ci, None
            c, _ = jax.lax.scan(outer, x, None, length=4)
            return c.sum()

        x = jnp.ones((32, 32))
        an = H.analyze_hlo(lower_text(f, x), world=1)
        want = 4 * 5 * 2 * 32 * 32 * 32
        assert an.dot_flops == pytest.approx(want, rel=0.01)


class TestDotFlops:
    def test_plain_matmul(self):
        f = lambda a, b: a @ b
        a = jnp.ones((64, 128))
        b = jnp.ones((128, 256))
        an = H.analyze_hlo(lower_text(f, a, b), world=1)
        assert an.dot_flops == pytest.approx(2 * 64 * 128 * 256, rel=0.01)

    def test_batched_einsum(self):
        f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
        a = jnp.ones((4, 32, 64))
        b = jnp.ones((4, 64, 16))
        an = H.analyze_hlo(lower_text(f, a, b), world=1)
        assert an.dot_flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


class TestCollectives:
    def test_group_size_parse(self):
        assert H._group_size("replica_groups=[2,4]<=[8]", 8) == 4
        assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 8) == 4
        assert H._group_size("no groups here", 8) == 8

    def test_ring_factors(self):
        # synthetic single-op module
        hlo = """
HloModule m

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p), replica_groups=[1,4]<=[4], to_apply=%add
}
"""
        an = H.analyze_hlo(hlo, world=4)
        # all-reduce ring traffic = 2·S·(n−1)/n
        assert an.collective_bytes == pytest.approx(2 * 256 * 3 / 4)
        assert an.collective_counts.get("all-reduce") == 1
