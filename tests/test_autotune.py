"""Tests for the schedule autotuner (core/autotune.py).

Covers the layer's contract: candidate legality (lane divisibility, VMEM
budget, lowering rejections), cost-model ranking determinism, cache
hit/miss/invalidation keyed on the nest, and on-disk persistence
round-trips.
"""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import autotune, compiler
from repro.core.autotune import (ScheduleCache, cache_key,
                                 candidate_schedules, model_cost,
                                 rank_candidates, schedule_is_legal)
from repro.core.lowering import DEFAULT_SCHEDULE, Schedule, ssr_call

RNG = np.random.default_rng(11)


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


@pytest.fixture
def cache(tmp_path):
    return ScheduleCache(path=str(tmp_path / "sched"))


class TestLegality:
    def test_default_is_legal_everywhere(self):
        for nest in (compiler.dot_product_nest(2048),
                     compiler.elementwise_nest(1024),
                     compiler.gemm_nest(32, 32, 32)):
            ok, why = schedule_is_legal(nest, DEFAULT_SCHEDULE)
            assert ok, why

    def test_lane_divisibility_rejected(self):
        nest = compiler.dot_product_nest(2048)
        ok, why = schedule_is_legal(nest, Schedule(lanes=100))
        assert not ok and "lane" in why
        ok, why = schedule_is_legal(nest, Schedule(lanes=64))
        assert not ok

    def test_vmem_budget_rejected(self):
        # 32768×1024 f32 blocks, double-buffered across three streams +
        # accumulator scratch, blow straight through the 64 MiB budget.
        nest = compiler.dot_product_nest(1 << 26)
        ok, why = schedule_is_legal(nest, Schedule(rows=32768, lanes=1024))
        assert not ok and "VMEM" in why

    def test_lowering_rejections_propagate(self):
        # axis_order on the flat path is a LoweringError -> illegal
        nest = compiler.dot_product_nest(2048)
        ok, why = schedule_is_legal(nest, Schedule(axis_order=(0,)))
        assert not ok and "lowering rejected" in why

    def test_axis_order_contraction_must_trail(self):
        nest = compiler.gemm_nest(64, 64, 64)
        ok, why = schedule_is_legal(nest, Schedule(axis_order=(2, 0, 1)))
        assert not ok and "lowering rejected" in why
        ok, why = schedule_is_legal(nest, Schedule(axis_order=(1, 0, 2)))
        assert ok, why

    def test_max_dims_enforced_at_nest_construction(self):
        from repro.core.stream import MAX_DIMS

        with pytest.raises(ValueError, match="exceeds AGU dims"):
            compiler.LoopNest(bounds=(2,) * (MAX_DIMS + 1), refs=(),
                              compute_per_level=(1,) * (MAX_DIMS + 1))

    def test_candidates_all_legal_default_first(self):
        nest = compiler.gemm_nest(32, 32, 32)
        cands = candidate_schedules(nest)
        assert cands[0] == DEFAULT_SCHEDULE
        for s in cands:
            ok, why = schedule_is_legal(nest, s)
            assert ok, (s, why)


class TestRanking:
    def test_deterministic(self):
        nest = compiler.dot_product_nest(5000)
        cands = candidate_schedules(nest)
        a = rank_candidates(nest, cands, top_k=6)
        b = rank_candidates(nest, cands, top_k=6)
        assert a == b

    def test_padding_blowup_charged(self):
        # 1000 elements: a 32×512 block pads to 16384, the default to 1024
        nest = compiler.dot_product_nest(1000)
        assert model_cost(nest, Schedule(rows=32, lanes=512)) > \
            model_cost(nest, DEFAULT_SCHEDULE)

    def test_step_overhead_rewards_bigger_blocks(self):
        # 8192 exact elements: same instruction count either way, fewer
        # grid steps for the bigger block
        nest = compiler.dot_product_nest(8192)
        assert model_cost(nest, Schedule(rows=16, lanes=256)) < \
            model_cost(nest, DEFAULT_SCHEDULE)

    def test_default_always_survives_prune(self):
        nest = compiler.dot_product_nest(8192)
        cands = candidate_schedules(nest)
        kept = rank_candidates(nest, cands, top_k=2)
        assert DEFAULT_SCHEDULE in kept

    def test_equal_geometry_candidates_collapse(self):
        # at 32^3 every tile clamps to the padded dims: all tile-factor /
        # axis-order variants lower identically and must not be measured
        # as separate candidates
        nest = compiler.gemm_nest(32, 32, 32)
        fp = autotune.schedule_fingerprint
        assert fp(nest, DEFAULT_SCHEDULE) == \
            fp(nest, Schedule(lanes_tile_factor=1, rows_tile_factor=8))
        assert fp(nest, DEFAULT_SCHEDULE) == \
            fp(nest, Schedule(axis_order=(1, 0, 2)))
        kept = rank_candidates(nest, candidate_schedules(nest), top_k=8)
        fps = [fp(nest, s) for s in kept]
        assert len(fps) == len(set(fps))


class TestCacheKeys:
    def test_nest_change_changes_key(self):
        ops = {"A": ((2048,), "float32"), "B": ((2048,), "float32")}
        k1 = cache_key(compiler.dot_product_nest(2048), ops)
        k2 = cache_key(compiler.dot_product_nest(4096), ops)
        assert k1 != k2

    def test_shape_dtype_mode_cores_change_key(self):
        nest = compiler.dot_product_nest(2048)
        ops = {"A": ((2048,), "float32"), "B": ((2048,), "float32")}
        base = cache_key(nest, ops)
        assert base != cache_key(
            nest, {"A": ((4096,), "float32"), "B": ((4096,), "float32")})
        assert base != cache_key(
            nest, {"A": ((2048,), "bfloat16"), "B": ((2048,), "bfloat16")})
        assert base != cache_key(nest, ops, mode="map")
        assert base != cache_key(nest, ops, cores=4)
        assert base != cache_key(nest, ops, backend="tpu")
        assert base == cache_key(nest, ops)  # stable


class TestPersistence:
    def test_roundtrip_across_instances(self, tmp_path):
        path = str(tmp_path / "sched")
        sched = Schedule(rows=16, lanes=256, axis_order=None)
        ScheduleCache(path=path).put("k1", sched, meta={"tuned_us": 1.0})
        fresh = ScheduleCache(path=path)          # no shared memory
        assert fresh.get("k1") == sched
        doc = fresh.meta("k1")
        assert doc["meta"]["tuned_us"] == 1.0

    def test_axis_order_and_factors_roundtrip(self, cache):
        sched = Schedule(rows=4, lanes=128, lanes_tile_factor=2,
                         rows_tile_factor=8, axis_order=(1, 0, 2),
                         acc_dtype="float32")
        cache.put("k2", sched)
        again = ScheduleCache(path=cache.path)
        assert again.get("k2") == sched

    def test_miss_returns_none(self, cache):
        assert cache.get("nope") is None

    def test_invalidation(self, cache):
        cache.put("k3", DEFAULT_SCHEDULE)
        assert cache.get("k3") is not None
        assert cache.invalidate("k3")
        assert cache.get("k3") is None
        assert not cache.invalidate("k3")  # already gone

    def test_clear_empties_disk(self, cache):
        cache.put("a", DEFAULT_SCHEDULE)
        cache.put("b", Schedule(rows=16))
        assert cache.clear() == 2
        assert cache.keys() == []

    def test_version_mismatch_ignored(self, cache):
        cache.put("k4", DEFAULT_SCHEDULE)
        f = os.path.join(cache.path, "k4.json")
        doc = json.load(open(f))
        doc["version"] = -1
        json.dump(doc, open(f, "w"))
        assert ScheduleCache(path=cache.path).get("k4") is None

    def test_corrupt_file_is_a_miss(self, cache):
        os.makedirs(cache.path, exist_ok=True)
        with open(os.path.join(cache.path, "bad.json"), "w") as f:
            f.write("{not json")
        assert cache.get("bad") is None


class TestAutotuneEndToEnd:
    def _tune(self, cache, n=2048, **kw):
        nest = compiler.dot_product_nest(n)
        ops = {"A": arr(n), "B": arr(n)}
        body = lambda a, b: a * b  # noqa: E731
        return autotune.autotune(
            nest, body, ops, mode="reduce",
            candidates=[DEFAULT_SCHEDULE, Schedule(rows=16, lanes=128)],
            warmup=1, iters=1, cache=cache, **kw), nest, ops, body

    def test_winner_is_measured_and_committed(self, cache):
        res, nest, ops, body = self._tune(cache)
        assert res.measured == 2 and not res.from_cache
        assert cache.get(res.key) == res.schedule
        # the winner's kernel agrees with the default's
        d = ssr_call(nest, body, ops)
        t = ssr_call(nest, body, ops, schedule=res.schedule)
        np.testing.assert_allclose(float(d), float(t), rtol=1e-6)

    def test_second_call_hits_cache(self, cache):
        res1, *_ = self._tune(cache)
        res2, *_ = self._tune(cache)
        assert res2.from_cache and res2.measured == 0
        assert res2.schedule == res1.schedule

    def test_force_remeasures(self, cache):
        self._tune(cache)
        res, *_ = self._tune(cache, force=True)
        assert not res.from_cache and res.measured == 2

    def test_nest_change_is_a_cache_miss(self, cache):
        self._tune(cache, n=2048)
        res, *_ = self._tune(cache, n=4096)
        assert not res.from_cache   # different nest -> different key

    def test_lookup_returns_winner_then_default_after_invalidate(self, cache):
        res, nest, ops, _ = self._tune(cache)
        assert autotune.lookup(nest, ops, mode="reduce",
                               cache=cache) == res.schedule
        assert autotune.invalidate(nest, ops, mode="reduce", cache=cache)
        assert autotune.lookup(nest, ops, mode="reduce",
                               cache=cache) == DEFAULT_SCHEDULE

    def test_epoch_bumps_on_commit(self, cache):
        e0 = autotune.epoch()
        self._tune(cache)
        assert autotune.epoch() > e0
