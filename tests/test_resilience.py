"""Chaos harness for the resilience layer (core/resilience.py).

Sweeps every fault seam × fault kind through the dispatch stack and
asserts the ISSUE-10 contract: each run ends in either a bit-correct
result delivered via the recorded fallback ladder (degraded counter
moved, poisoned cache entry quarantined, FallbackEvent logged) or a
pinned *typed* error — never a raw traceback out of cache internals,
and never a masked user error.  Also covers the crash-safe multi-process
schedule cache: cross-process negative-cache staleness, corrupt-file
quarantine + recovery (hypothesis fuzz), bounded retry on transient
commit I/O, and a ≥4-worker concurrent lookup/put/invalidate stress.
"""

import itertools
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import autotune, compiler, lowering, resilience
from repro.core.autotune import ScheduleCache
from repro.core.lowering import DEFAULT_SCHEDULE, Schedule, ssr_call
from repro.core.resilience import (FaultSpec, InjectedFault, InjectedOSError,
                                   KINDS, SEAMS, inject_faults, parse_faults,
                                   retry)
from repro.kernels import frontend

RNG = np.random.default_rng(29)

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

TUNED = Schedule(rows=16)          # legal non-default geometry for the nests


def arr(n):
    return jnp.asarray(RNG.standard_normal(n), jnp.float32)


def _sub_env(cache_dir):
    """Subprocess environment: isolated cache, NO ambient chaos matrix."""
    env = dict(os.environ)
    env["REPRO_SCHEDULE_CACHE"] = str(cache_dir)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)
    return env


@pytest.fixture(autouse=True)
def _clean_resilience():
    # consume any ambient REPRO_FAULTS (the CI chaos matrix) so each test
    # arms exactly the faults it means to, and leave nothing armed behind
    resilience.reset()
    lowering.reset_dispatch_stats()
    frontend.reset_dispatch_stats()
    yield
    resilience.reset()


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "sch"))
    lowering.clear_caches()        # seams must fire, not hit stale kernels
    yield autotune.global_cache()


class TestInjector:
    def test_parse_faults(self):
        specs = parse_faults("cache.read, cache.write:oserror:2,compile")
        assert [(s.seam, s.kind, s.times) for s in specs] == [
            ("cache.read", "fault", 1), ("cache.write", "oserror", 2),
            ("compile", "fault", 1)]

    def test_parse_rejects_unknown_seam_and_kind(self):
        with pytest.raises(ValueError, match="unknown fault seam"):
            parse_faults("cache.reed")
        with pytest.raises(ValueError, match="unknown fault kind"):
            parse_faults("compile:tornado")

    def test_context_manager_fires_then_exhausts(self):
        with inject_faults("compile") as specs:
            with pytest.raises(InjectedFault) as ei:
                resilience.inject("compile")
            assert ei.value.seam == "compile"
            resilience.inject("compile")      # times=1: now exhausted
            resilience.inject("cache.read")   # other seams untouched
        assert specs[0].fired == 1
        assert resilience.FAULT_STATS["injected"] == 1
        resilience.inject("compile")          # disarmed on exit

    def test_oserror_kind_is_an_oserror(self):
        with inject_faults("cache.write", kind="oserror"):
            with pytest.raises(OSError):
                resilience.inject("cache.write")

    def test_env_arming_and_consumption(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "lowering")
        resilience.reset_faults(reload_env=True)
        with pytest.raises(InjectedFault):
            resilience.inject("lowering")
        # default reset marks the env consumed: ambient matrix is inert
        resilience.reset_faults()
        resilience.inject("lowering")

    def test_unlimited_times(self):
        spec = FaultSpec(seam="compile", times=-1)
        assert not spec.exhausted()
        spec.fired = 100
        assert not spec.exhausted()


class TestRetry:
    def test_absorbs_transient_then_succeeds(self):
        calls, slept, retried = [], [], []
        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"
        got = retry(flaky, attempts=3, sleep=slept.append,
                    on_retry=lambda a, e: retried.append(a))
        assert got == "ok" and len(calls) == 3
        assert retried == [1, 2] and len(slept) == 2

    def test_budget_exhausted_propagates_last_error(self):
        def always():
            raise OSError("persistent")
        with pytest.raises(OSError, match="persistent"):
            retry(always, attempts=3, sleep=lambda _: None)

    def test_non_retriable_propagates_immediately(self):
        calls = []
        def boom():
            calls.append(1)
            raise ValueError("user error")
        with pytest.raises(ValueError):
            retry(boom, attempts=5, sleep=lambda _: None)
        assert len(calls) == 1

    def test_backoff_is_bounded(self):
        slept = []
        def always():
            raise OSError("x")
        with pytest.raises(OSError):
            retry(always, attempts=4, base_delay=0.004, max_delay=0.01,
                  sleep=slept.append)
        assert len(slept) == 3 and all(0 <= d <= 0.01 for d in slept)


class TestChaosSweep:
    """Every seam × kind through transparent tuned ssr_call dispatch."""

    def _setup_problem(self, cache):
        n = 2048
        x, y = arr(n), arr(n)
        nest = compiler.dot_product_nest(n)
        operands = {"A": x, "B": y}
        body = lambda a, b: a * b  # noqa: E731
        healthy = ssr_call(nest, body, operands)   # default-schedule result
        key = autotune.cache_key(nest, operands, mode="reduce",
                                 out_dtype="float32")
        cache.put(key, TUNED)
        return nest, body, operands, key, healthy

    @pytest.mark.parametrize("seam,kind",
                             list(itertools.product(SEAMS, KINDS)))
    def test_sweep(self, seam, kind, tuned_env):
        nest, body, operands, key, healthy = self._setup_problem(tuned_env)
        resilience.reset_fallback_log()
        lowering.reset_dispatch_stats()
        with inject_faults(seam, kind=kind) as specs:
            got = ssr_call(nest, body, operands)
        np.testing.assert_allclose(np.asarray(got), np.asarray(healthy),
                                   rtol=1e-5, atol=1e-6)
        stats = lowering.DISPATCH_STATS
        events = resilience.fallback_events()
        if seam == "cache.read":
            # lookup failed before any tuned kernel existed: fall back to
            # the default schedule, do NOT quarantine (the entry is fine)
            assert specs[0].fired == 1
            assert stats["fallbacks"] == 1 and stats["degraded"] == 0
            assert [e.to_schedule for e in events] == ["default"]
            assert tuned_env.get(key) == TUNED
        elif seam in ("lowering", "compile"):
            # committed tuned schedule failed to lower/compile: quarantine
            # the poisoned entry and re-dispatch on the default schedule
            assert specs[0].fired == 1
            assert stats["degraded"] == 1
            assert [(e.seam, e.site, e.key) for e in events] == \
                [(seam, "ssr_call", key)]
            assert tuned_env.get(key) is None
            assert os.path.exists(
                os.path.join(tuned_env.path, f"{key}.json.corrupt"))
            # ...and the ladder is sticky: the next call runs default
            # without re-tripping anything
            again = ssr_call(nest, body, operands)
            np.testing.assert_allclose(np.asarray(again),
                                       np.asarray(healthy), rtol=1e-5,
                                       atol=1e-6)
        else:   # cache.write / measure: no such seam on the dispatch path
            assert specs[0].fired == 0
            assert stats["fallbacks"] == 0 and stats["degraded"] == 0

    def test_chain_degrades(self, tuned_env):
        from repro.core.compiler import Direction, LoopNest, MemRef
        from repro.core.lowering import ssr_chain_call

        n = 1024
        x, y = arr(n), arr(n)
        producer = LoopNest(
            bounds=(n,),
            refs=(MemRef("X", Direction.READ, (1,)),
                  MemRef("Y", Direction.READ, (1,)),
                  MemRef("T", Direction.WRITE, (1,))),
            compute_per_level=(2,))
        consumer = LoopNest(
            bounds=(n,),
            refs=(MemRef("T", Direction.READ, (1,)),),
            compute_per_level=(1,))
        nests = (producer, consumer)
        bodies = (lambda a, b: a * b, lambda t: t + 1.0)
        operands = {"X": x, "Y": y}
        healthy = ssr_chain_call(nests, bodies, operands)
        key = autotune.cache_key(nests[0], operands, mode="map",
                                 out_dtype="float32")
        tuned_env.put(key, TUNED)
        lowering.reset_dispatch_stats()
        with inject_faults("compile") as specs:
            got = ssr_chain_call(nests, bodies, operands)
        assert specs[0].fired == 1
        assert lowering.DISPATCH_STATS["degraded"] == 1
        assert tuned_env.get(key) is None
        np.testing.assert_allclose(np.asarray(got), np.asarray(healthy),
                                   rtol=1e-5, atol=1e-6)


class TestDegradationChain:
    def test_explicit_schedule_error_propagates(self, tuned_env):
        # a caller-pinned schedule is never degraded: masking would hide
        # their bug.  The error surfaces as the pinned typed InjectedFault.
        n = 2048
        nest = compiler.dot_product_nest(n)
        operands = {"A": arr(n), "B": arr(n)}
        with inject_faults("lowering"):
            with pytest.raises(InjectedFault):
                ssr_call(nest, lambda a, b: a * b, operands, schedule=TUNED)
        assert lowering.DISPATCH_STATS["degraded"] == 0

    def test_user_error_never_masked(self, tuned_env):
        n = 2048
        x, y = arr(n), arr(n)
        nest = compiler.dot_product_nest(n)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, TUNED)
        with pytest.raises(ValueError, match="missing operands"):
            ssr_call(nest, lambda a, b: a * b, {"A": x})  # B missing
        # the tuned entry is innocent: not quarantined, no fallback
        assert tuned_env.get(key) == TUNED
        assert lowering.DISPATCH_STATS["degraded"] == 0

    def test_nest_kernel_degrades_and_quarantines(self, tuned_env):
        from repro.kernels import reduction

        n = 2048
        x, y = arr(n), arr(n)
        healthy = reduction.ssr_dot(x, y)          # default pipeline
        nest = compiler.dot_product_nest(n)
        key = autotune.cache_key(nest, {"A": x, "B": y}, mode="reduce",
                                 out_dtype="float32")
        tuned_env.put(key, TUNED)
        frontend.reset_dispatch_stats()
        resilience.reset_fallback_log()
        with inject_faults("compile") as specs:
            got = reduction.ssr_dot(x, y)
        assert specs[0].fired == 1
        assert frontend.DISPATCH_STATS["degraded"] == 1
        assert tuned_env.get(key) is None          # quarantined
        sites = [e.site for e in resilience.fallback_events()]
        assert any(s.startswith("nest_kernel:") for s in sites)
        np.testing.assert_allclose(float(got), float(healthy), rtol=1e-5)

    def test_registry_baseline_fallback_opt_in(self, tuned_env):
        from repro.kernels import registry

        n = 2048
        x, y = arr(n), arr(n)
        want = registry.get("reduction").ref(x, y)
        resilience.reset_fallback_log()
        # unlimited compile faults: the streamed engine is down for good;
        # the opt-in ladder lands on the ssrcfg-off baseline
        with inject_faults("compile", times=-1):
            with pytest.raises(InjectedFault):
                registry.dispatch("reduction", x, y, ssr=True)  # no opt-in
            got = registry.dispatch("reduction", x, y, ssr=True,
                                    baseline_fallback=True)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        assert any(e.to_schedule == "baseline"
                   for e in resilience.fallback_events())

    def test_registry_baseline_fallback_env(self, tuned_env, monkeypatch):
        from repro.kernels import registry

        n = 1024
        x, y = arr(n), arr(n)
        monkeypatch.setenv("REPRO_BASELINE_FALLBACK", "1")
        with inject_faults("compile", times=-1):
            got = registry.dispatch("reduction", x, y, ssr=True)
        np.testing.assert_allclose(
            float(got), float(registry.get("reduction").ref(x, y)),
            rtol=1e-5)

    def test_cluster_lookup_degrades(self, tuned_env):
        from repro.parallel.cluster import cluster_call

        n = 2048
        nest = compiler.dot_product_nest(n)
        x, y = arr(n), arr(n)
        body = lambda a, b: a * b  # noqa: E731
        healthy = cluster_call(nest, body, {"A": x, "B": y}, cores=1)
        lowering.reset_dispatch_stats()
        with inject_faults("cache.read") as specs:
            got = cluster_call(nest, body, {"A": x, "B": y}, cores=1)
        assert specs[0].fired == 1
        assert lowering.DISPATCH_STATS["fallbacks"] == 1
        np.testing.assert_allclose(float(got), float(healthy), rtol=1e-5)


class TestCacheCrashSafety:
    def test_write_retry_absorbs_transient_oserror(self, tmp_path):
        cache = ScheduleCache(path=str(tmp_path / "c"))
        with inject_faults("cache.write", kind="oserror", times=2) as specs:
            cache.put("k", TUNED)
        assert specs[0].fired == 2
        assert cache.stats["retries"] >= 2
        assert cache.get("k") == TUNED
        assert not [n for n in os.listdir(cache.path)
                    if n.endswith(".tmp")]

    def test_write_retry_budget_exhausted_raises(self, tmp_path):
        cache = ScheduleCache(path=str(tmp_path / "c"))
        with inject_faults("cache.write", kind="oserror", times=3):
            with pytest.raises(OSError):
                cache.put("k", TUNED)
        assert cache.get("k") is None

    def test_write_hard_fault_not_retried(self, tmp_path):
        cache = ScheduleCache(path=str(tmp_path / "c"))
        with inject_faults("cache.write") as specs:
            with pytest.raises(InjectedFault):
                cache.put("k", TUNED)
        assert specs[0].fired == 1     # InjectedFault is not transient I/O

    def test_measure_fault_degrades_autotune_without_commit(self, tmp_path):
        n = 2048
        nest = compiler.dot_product_nest(n)
        operands = {"A": arr(n), "B": arr(n)}
        cache = ScheduleCache(path=str(tmp_path / "c"))
        with inject_faults("measure"):
            res = autotune.autotune(nest, lambda a, b: a * b, operands,
                                    cache=cache, iters=1, warmup=0)
        assert res.degraded and not res.committed
        assert res.schedule == DEFAULT_SCHEDULE
        assert cache.keys() == []


class TestCrossProcess:
    def test_negative_cache_busted_by_other_process_commit(self, tmp_path):
        path = str(tmp_path / "shared")
        local = ScheduleCache(path=path)
        key = "deadbeef01"
        assert local.get(key) is None          # negative-cached locally
        assert local.get(key) is None          # served from the miss cache
        e0 = autotune.epoch()
        code = textwrap.dedent("""
            import sys
            from repro.core.autotune import ScheduleCache
            from repro.core.lowering import Schedule
            ScheduleCache(path=sys.argv[1]).put(sys.argv[2],
                                                Schedule(rows=16))
        """)
        subprocess.run([sys.executable, "-c", code, path, key], check=True,
                       env=_sub_env(path), timeout=240)
        # pre-fix this get served the stale process-local negative cache;
        # the GENERATION probe must surface the other process's commit NOW
        assert local.get(key) == Schedule(rows=16)
        assert local.stats["generation_busts"] >= 1
        assert autotune.epoch() > e0           # pipeline caches rebuild too

    def test_multiprocess_stress(self, tmp_path):
        path = str(tmp_path / "shared")
        workers = 4
        worker = textwrap.dedent("""
            import random, sys
            from repro.core.autotune import ScheduleCache
            from repro.core.lowering import Schedule
            path, wid = sys.argv[1], int(sys.argv[2])
            rng = random.Random(1000 + wid)
            cache = ScheduleCache(path=path)
            keys = ["stress%02d" % i for i in range(8)]
            scheds = [Schedule(rows=16), Schedule(rows=32),
                      Schedule(lanes=256)]
            for _ in range(60):
                op = rng.choice(("put", "get", "get", "invalidate"))
                k = rng.choice(keys)
                if op == "put":
                    cache.put(k, rng.choice(scheds))
                elif op == "get":
                    s = cache.get(k)
                    assert s is None or isinstance(s, Schedule), s
                else:
                    cache.invalidate(k)
            print("WORKER-OK", wid)
        """)
        procs = [subprocess.Popen(
            [sys.executable, "-c", worker, path, str(i)],
            env=_sub_env(path), stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True) for i in range(workers)]
        for i, p in enumerate(procs):
            out, err = p.communicate(timeout=300)
            assert p.returncode == 0, f"worker {i} failed:\n{err}"
            assert f"WORKER-OK {i}" in out
        # no torn writes: every survivor parses as a current-version doc
        names = os.listdir(path)
        assert not [n for n in names if n.endswith(".tmp")]
        for n in names:
            if n.endswith(".json"):
                with open(os.path.join(path, n)) as f:
                    doc = json.load(f)
                assert doc["version"] == autotune.SCHEDULE_CACHE_VERSION
        # and the dir is still serviceable after the melee
        after = ScheduleCache(path=path)
        after.put("post-stress", TUNED)
        assert after.get("post-stress") == TUNED


class TestCorruptQuarantine:
    @settings(max_examples=20)
    @given(kind=st.sampled_from(["truncated", "garbage", "version-skew"]),
           cut=st.integers(min_value=0, max_value=60))
    def test_fuzz_corrupt_load_quarantines_put_recovers(self, kind, cut):
        with tempfile.TemporaryDirectory() as d:
            cache = ScheduleCache(path=d)
            cache.put("good", TUNED)           # healthy neighbour survives
            key = "fuzzkey"
            doc = {"version": autotune.SCHEDULE_CACHE_VERSION,
                   "schedule": TUNED.to_json()}
            text = json.dumps(doc)
            if kind == "truncated":
                payload = text[:min(cut, len(text) - 1)]
            elif kind == "garbage":
                payload = "".join(chr(33 + (cut * 7 + i) % 90)
                                  for i in range(cut + 1))
            else:
                payload = json.dumps({**doc, "version": -1})
            with open(os.path.join(d, f"{key}.json"), "w") as f:
                f.write(payload)
            assert cache.get(key) is None          # miss, not a crash
            assert cache.stats["quarantined"] == 1
            assert os.path.exists(os.path.join(d, f"{key}.json.corrupt"))
            assert cache.get("good") == TUNED      # neighbour untouched
            cache.put(key, Schedule(rows=32))      # put recovers the key
            assert cache.get(key) == Schedule(rows=32)

    def test_meta_quarantines_garbage(self, tmp_path):
        cache = ScheduleCache(path=str(tmp_path / "c"))
        os.makedirs(cache.path, exist_ok=True)
        with open(os.path.join(cache.path, "k.json"), "w") as f:
            f.write("{not json")
        assert cache.meta("k") is None
        assert cache.stats["quarantined"] == 1


class _FakeClock:
    """Deterministic perf_counter: each timed interval pops one planned dt."""

    def __init__(self, dts):
        self.dts = list(dts)
        self.t = 0.0
        self.phase = 0

    def __call__(self):
        if self.phase == 0:
            self.phase = 1
            return self.t
        self.phase = 0
        self.t += self.dts.pop(0) if self.dts else 1e-3
        return self.t


class TestStragglerIntegration:
    """runtime/fault.StragglerMonitor wired into autotune's measure loop."""

    def _race(self, monitor, tmp_path):
        from repro.runtime.fault import StragglerMonitor  # noqa: F401

        n = 2048
        nest = compiler.dot_product_nest(n)
        operands = {"A": arr(n), "B": arr(n)}
        cands = [DEFAULT_SCHEDULE, TUNED]
        survivors = autotune.rank_candidates(nest, cands, top_k=2)
        # the default's sample is poisoned by a 1.0 s stall; the genuinely
        # slower tuned candidate times a clean 0.002 s
        dts = [1.0 if s == DEFAULT_SCHEDULE else 0.002 for s in survivors]
        # a flagged sample re-races once: the re-race of the default's
        # stall comes in at its true 0.001 s
        clock_seq = []
        for s, dt in zip(survivors, dts):
            clock_seq.append(dt)
            if s == DEFAULT_SCHEDULE:
                clock_seq.append(0.001)   # consumed only if re-raced
        cache = ScheduleCache(path=str(tmp_path / "c"))
        res = autotune.autotune(
            nest, lambda a, b: a * b, operands, cache=cache,
            candidates=cands, top_k=2, warmup=0, iters=1,
            call=lambda sched: jnp.float32(0.0),
            clock=_FakeClock(clock_seq), straggler=monitor)
        return res, cache, nest, operands

    def test_straggler_flagged_and_reraced_not_committed(self, tmp_path):
        from repro.runtime.fault import StragglerMonitor

        # seeded stats: clean step time ~2 ms, so the 1.0 s stall is an
        # outlier but the tuned candidate's honest 2 ms is not
        monitor = StragglerMonitor(warmup_steps=0, mean=0.002, var=1e-8,
                                   n=5)
        res, cache, nest, operands = self._race(monitor, tmp_path)
        assert res.stragglers == 1
        assert res.schedule == DEFAULT_SCHEDULE
        # the committed entry resolves to the default: the poisoned race
        # did NOT commit a slower-than-default winner
        assert autotune.lookup(nest, operands, cache=cache) == \
            DEFAULT_SCHEDULE

    def test_without_monitor_the_poisoned_race_lies(self, tmp_path):
        from repro.runtime.fault import StragglerMonitor

        # control: an effectively-disabled monitor lets the stalled sample
        # decide, committing the genuinely slower tuned schedule — this is
        # the failure mode the integration exists to prevent
        blind = StragglerMonitor(warmup_steps=0, mean=0.002, var=1e-8, n=5,
                                 threshold_sigma=1e9)
        res, cache, nest, operands = self._race(blind, tmp_path)
        assert res.stragglers == 0
        assert res.schedule == TUNED
